//! ODE solvers for the EDM probability-flow ODE `dx/dt = eps(x, t)`.
//!
//! All solvers plug into one driver built around the paper's uniform
//! first-order-representable step (Eq. 16):
//!
//! ```text
//! x_{t_{i-1}} = phi(x_{t_i}, d_{t_i}, t_i, t_{i-1})
//! ```
//!
//! where `d_{t_i}` is the *primary* model evaluation of the step. The
//! driver evaluates `d`, offers it to an optional [`DirectionHook`]
//! (PAS's correction point, Algorithms 1–2), then lets the solver combine
//! it with history. Multistep solvers receive the corrected `d` in their
//! history exactly as Algorithm 1 line 17 requires.
//!
//! Two drivers exist:
//!
//! * [`engine::SamplerEngine`] — the production path: preallocated
//!   ping-pong workspace, optional trajectory recording
//!   ([`engine::Record`]), row-sharded parallel stepping. [`run_solver`]
//!   is a thin compatibility wrapper over it.
//! * [`run_solver_legacy`] — the original allocate-per-step reference
//!   driver, kept as the bit-exactness oracle for the engine parity tests
//!   and the `solver_step` bench baseline.
//!
//! History is exposed to solvers through [`NodeView`], a cheap read-only
//! view that works over both nested `Vec<Vec<f64>>` storage (legacy
//! driver, trainer) and the engine's flat ring buffers.
//!
//! # Scratch arenas
//!
//! Solvers do not heap-allocate inside [`Solver::step`]. A solver
//! declares its per-step temporary storage via [`Solver::scratch_spec`]
//! (so much per batch row, so much flat) and carves the actual buffers
//! out of a caller-owned [`StepScratch`] arena at step time. The engine
//! preallocates one arena per run and hands every parallel row-chunk its
//! own disjoint slice, which is what makes the whole registry — including
//! the multi-eval Heun/DPM-Solver-2 and the history-hungry DPM++/UniPC —
//! zero-allocation in steady state (`tests/alloc_audit.rs` enforces
//! this). One-shot callers size an arena directly:
//!
//! ```
//! use pas::solvers::{ScratchSpec, StepScratch};
//!
//! // A solver that needs two f64 temporaries per batch row plus three
//! // flat coefficients would report:
//! let spec = ScratchSpec { per_row: 2, flat: 3 };
//! let rows = 4;
//! let mut buf = vec![0.0; spec.len_for(rows)];
//!
//! // Each step re-wraps the same buffer; `take` carves disjoint
//! // sub-buffers off the front (no zeroing — callers overwrite).
//! let mut scratch = StepScratch::new(&mut buf);
//! let per_row_block = scratch.take(2 * rows);
//! let coefs = scratch.take(3);
//! per_row_block[0] = 1.0;
//! coefs[2] = -0.5;
//! assert_eq!(scratch.remaining(), 0);
//! ```
//!
//! NFE accounting is explicit: `steps_for_nfe` refuses budgets the solver
//! cannot hit exactly (e.g. DPM-Solver-2 at odd NFE — the "\\" cells of the
//! paper's tables).

pub mod euler;
pub mod rk;
pub mod multistep;
pub mod dpmpp;
pub mod unipc;
pub mod registry;
pub mod engine;

use crate::schedule::Schedule;
use crate::score::EpsModel;
use std::marker::PhantomData;

/// Read-only view over the recorded per-node batch rows (`xs` states or
/// `ds` directions). Row `i` is the flat `(n, dim)` buffer at node `i`;
/// indexing is by *absolute node index*, matching the paper's `ts[j]`
/// grid.
///
/// Backed either by nested `Vec<Vec<f64>>` rows (legacy driver, trainer,
/// tests) or by the engine's flat — possibly ring — storage. Ring-backed
/// views only retain the trailing window the registered solvers need
/// (see [`engine`]); indexing an evicted node panics.
#[derive(Clone, Copy)]
pub struct NodeView<'a> {
    inner: Inner<'a>,
}

#[derive(Clone, Copy)]
enum Inner<'a> {
    Nested {
        rows: &'a [Vec<f64>],
        col0: usize,
        /// `None` = full rows (whatever each row's length is).
        cols: Option<usize>,
    },
    Flat {
        ptr: *const f64,
        row_len: usize,
        /// Committed (logical) rows; the retained window is the trailing
        /// `cap_rows - 1` of them while a write is in flight.
        len: usize,
        cap_rows: usize,
        col0: usize,
        cols: usize,
        _pd: PhantomData<&'a [f64]>,
    },
}

// SAFETY: a NodeView only ever yields shared `&[f64]` access; the engine
// guarantees the flat variant's pointer stays valid and disjoint from the
// single in-flight write row for the view's lifetime.
unsafe impl Send for NodeView<'_> {}
unsafe impl Sync for NodeView<'_> {}

impl<'a> NodeView<'a> {
    /// View over nested rows (each row one flat `(n, dim)` buffer).
    pub fn nested(rows: &'a [Vec<f64>]) -> NodeView<'a> {
        NodeView {
            inner: Inner::Nested {
                rows,
                col0: 0,
                cols: None,
            },
        }
    }

    /// View over a dense row-major matrix holding `data.len() / row_len`
    /// committed rows.
    pub fn flat(data: &'a [f64], row_len: usize) -> NodeView<'a> {
        assert!(row_len > 0 && data.len() % row_len == 0, "flat view shape");
        let len = data.len() / row_len;
        NodeView {
            inner: Inner::Flat {
                ptr: data.as_ptr(),
                row_len,
                len,
                // No in-flight write row for a plain matrix view, so the
                // strict eviction check (`node + cap_rows > len`) must
                // admit every committed row — hence len + 1, not len.
                cap_rows: len + 1,
                col0: 0,
                cols: row_len,
                _pd: PhantomData,
            },
        }
    }

    /// Ring view used by the engine; `len` committed rows over `cap_rows`
    /// slots (slot = node % cap_rows). The unbound lifetime is pinned by
    /// the caller's signature.
    pub(crate) fn ring(
        ptr: *const f64,
        row_len: usize,
        len: usize,
        cap_rows: usize,
    ) -> NodeView<'a> {
        NodeView {
            inner: Inner::Flat {
                ptr,
                row_len,
                len,
                cap_rows,
                col0: 0,
                cols: row_len,
                _pd: PhantomData,
            },
        }
    }

    /// Number of committed node rows.
    pub fn len(&self) -> usize {
        match self.inner {
            Inner::Nested { rows, .. } => rows.len(),
            Inner::Flat { len, .. } => len,
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    /// Row at absolute node index `node`.
    pub fn row(&self, node: usize) -> &'a [f64] {
        match self.inner {
            Inner::Nested { rows, col0, cols } => {
                let r: &'a [f64] = &rows[node];
                match cols {
                    Some(c) => &r[col0..col0 + c],
                    None => &r[col0..],
                }
            }
            Inner::Flat {
                ptr,
                row_len,
                len,
                cap_rows,
                col0,
                cols,
                ..
            } => {
                assert!(node < len, "node {node} not committed (len {len})");
                assert!(
                    node + cap_rows > len,
                    "node {node} evicted from the history window (len {len}, cap {cap_rows})"
                );
                let slot = node % cap_rows;
                // SAFETY: slot < cap_rows, the backing allocation holds
                // cap_rows * row_len elements, and the engine never hands
                // out a view whose retained window overlaps its write row.
                unsafe {
                    std::slice::from_raw_parts(ptr.add(slot * row_len + col0), cols)
                }
            }
        }
    }

    /// Sub-view restricted to columns `[c0, c0 + c)` of every row (used
    /// by the engine to shard a batch row-range across threads; `c0` is
    /// relative to this view's own column window).
    pub fn cols(&self, c0: usize, c: usize) -> NodeView<'a> {
        match self.inner {
            Inner::Nested { rows, col0, .. } => NodeView {
                inner: Inner::Nested {
                    rows,
                    col0: col0 + c0,
                    cols: Some(c),
                },
            },
            Inner::Flat {
                ptr,
                row_len,
                len,
                cap_rows,
                col0,
                cols,
                ..
            } => {
                assert!(c0 + c <= cols, "column sub-view out of range");
                NodeView {
                    inner: Inner::Flat {
                        ptr,
                        row_len,
                        len,
                        cap_rows,
                        col0: col0 + c0,
                        cols: c,
                        _pd: PhantomData,
                    },
                }
            }
        }
    }
}

impl std::ops::Index<usize> for NodeView<'_> {
    type Output = [f64];

    fn index(&self, node: usize) -> &[f64] {
        self.row(node)
    }
}

/// Per-step context handed to solvers and hooks.
pub struct StepCtx<'a> {
    /// 0-based step index: transition `ts[j] -> ts[j+1]`.
    pub j: usize,
    /// Paper-style index `i = N - j` (runs N..1).
    pub i_paper: usize,
    pub t: f64,
    pub t_next: f64,
    pub sched: &'a Schedule,
    /// States at nodes `ts[0..=j]` (so `xs[j]` is the current state).
    pub xs: NodeView<'a>,
    /// Corrected primary directions at `ts[0..j]` (past steps only).
    pub ds: NodeView<'a>,
}

impl StepCtx<'_> {
    /// Step size `t_next - t` (negative: time decreases).
    pub fn h(&self) -> f64 {
        self.t_next - self.t
    }

    /// Log-SNR half-step: `lambda = -ln t` in EDM.
    pub fn lambda(&self, t: f64) -> f64 {
        -t.ln()
    }
}

/// Scratch requirements of one [`Solver::step`] call, in `f64` elements.
/// See the module docs for the arena protocol.
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub struct ScratchSpec {
    /// Elements needed per batch row (dim-proportional temporaries such
    /// as Heun's midpoint state or DPM++'s data predictions). A chunk of
    /// `rows` rows needs `per_row * rows` of these.
    pub per_row: usize,
    /// Elements independent of the batch size (coefficient vectors,
    /// small linear systems).
    pub flat: usize,
}

impl ScratchSpec {
    /// No scratch at all (the default for simple solvers).
    pub const NONE: ScratchSpec = ScratchSpec { per_row: 0, flat: 0 };

    /// Total arena length for a chunk of `rows` batch rows.
    pub fn len_for(&self, rows: usize) -> usize {
        self.per_row * rows + self.flat
    }
}

/// A bump-carved `f64` arena handed to [`Solver::step`]. `take` splits
/// disjoint `&mut` sub-buffers off the front, so a solver can hold all of
/// its temporaries simultaneously without heap allocation. Contents are
/// NOT zeroed between steps — solvers must fully overwrite what they
/// read.
pub struct StepScratch<'a> {
    rest: &'a mut [f64],
}

impl<'a> StepScratch<'a> {
    /// Wrap a caller-owned buffer (sized via [`ScratchSpec::len_for`]).
    pub fn new(buf: &'a mut [f64]) -> StepScratch<'a> {
        StepScratch { rest: buf }
    }

    /// Carve `len` elements off the front. Panics if the arena was sized
    /// below the solver's declared [`Solver::scratch_spec`].
    pub fn take(&mut self, len: usize) -> &'a mut [f64] {
        let rest = std::mem::take(&mut self.rest);
        assert!(
            len <= rest.len(),
            "StepScratch underprovisioned: take({len}) with {} elements left \
             (arena must be sized by the solver's scratch_spec)",
            rest.len()
        );
        let (head, tail) = rest.split_at_mut(len);
        self.rest = tail;
        head
    }

    /// Elements not yet carved out.
    pub fn remaining(&self) -> usize {
        self.rest.len()
    }
}

/// Hook invoked right after the primary model evaluation of each step.
/// PAS implements this; tests use it to inject faults.
pub trait DirectionHook {
    /// May modify `d` (the batch of primary directions, `(n, dim)`)
    /// in place. Returns true if a correction was applied.
    fn correct(&mut self, ctx: &StepCtx<'_>, x: &[f64], n: usize, d: &mut [f64]) -> bool;
}

/// A no-op hook.
pub struct NoHook;

impl DirectionHook for NoHook {
    fn correct(&mut self, _ctx: &StepCtx<'_>, _x: &[f64], _n: usize, _d: &mut [f64]) -> bool {
        false
    }
}

/// One deterministic ODE solver.
pub trait Solver: Send + Sync {
    fn name(&self) -> &str;

    /// Model evaluations consumed per step (1 unless noted).
    fn evals_per_step(&self) -> usize {
        1
    }

    /// Steps affordable with an exact NFE budget; `None` if the budget is
    /// not representable (paper's "\\" cells).
    fn steps_for_nfe(&self, nfe: usize) -> Option<usize> {
        let e = self.evals_per_step();
        if nfe == 0 || nfe % e != 0 {
            None
        } else {
            Some(nfe / e)
        }
    }

    /// `d x_next / d d_current` when the primary direction enters the
    /// update linearly with a scalar coefficient (required by PAS training
    /// to backpropagate to the coordinates without autodiff); `None` for
    /// solvers whose step is nonlinear in `d` (Heun, DPM-Solver-2) or that
    /// re-use `d` nonlinearly (UniPC corrector).
    fn gamma(&self, ctx: &StepCtx<'_>) -> Option<f64>;

    /// True (the default) when `step` computes each batch row purely from
    /// that row's slice of `x`, `d` and the history views — i.e. no
    /// cross-row reductions. The engine only shards the batch across
    /// threads when this holds; every registered solver qualifies, and
    /// row-sharding then preserves the per-row f64 operation order, so
    /// results are bit-identical for any thread count. Multi-eval solvers
    /// additionally route their internal model evaluations through
    /// per-chunk `eval_batch` calls, so the model must be row-independent
    /// too ([`EpsModel::rows_independent`]) for the shard to engage.
    fn row_independent(&self) -> bool {
        true
    }

    /// Scratch [`Solver::step`] needs for a batch of `n` rows of
    /// dimension `dim`. Callers size a [`StepScratch`] arena with
    /// [`ScratchSpec::len_for`]; the engine does this once per run and
    /// hands each parallel row-chunk its own disjoint slice.
    fn scratch_spec(&self, _dim: usize, _n: usize) -> ScratchSpec {
        ScratchSpec::NONE
    }

    /// Deepest history lookback `step` performs, in *steps back from the
    /// current node*: at step `j` the solver promises to read only
    /// `ctx.xs[j - hist_depth() ..= j]` and `ctx.ds[j - hist_depth() ..
    /// j]` (clamped at 0). `0` therefore means "current state and primary
    /// direction only" — no history at all. Drivers use this to stage /
    /// retain only the nodes actually read: the [`engine::SlotEngine`]
    /// serve path gathers `hist_depth()`-deep windows per tick instead of
    /// the full `engine::HIST_NODES - 1` window, so single-step solvers
    /// stop paying the multistep staging cost.
    ///
    /// The promise covers the whole step context — [`DirectionHook`]s run
    /// against the same trimmed views (the PAS hook reads no history, so
    /// this is safe for every registered hook). Returning a depth smaller
    /// than what `step` actually reads makes the ring views panic on the
    /// evicted node; the conservative default — the deepest window the
    /// engine can retain — is always correct for solvers written against
    /// [`engine::HIST_NODES`].
    fn hist_depth(&self) -> usize {
        engine::HIST_NODES - 2
    }

    /// Advance the batch: write `x_{t_{j+1}}` into `out`. `scratch` must
    /// provide at least `scratch_spec(dim, n).len_for(n)` elements; step
    /// performs no heap allocation.
    #[allow(clippy::too_many_arguments)]
    fn step(
        &self,
        model: &dyn EpsModel,
        ctx: &StepCtx<'_>,
        x: &[f64],
        d: &[f64],
        n: usize,
        out: &mut [f64],
        scratch: &mut StepScratch<'_>,
    );
}

/// Result of a sampling run.
pub struct SolveRun {
    /// Final samples (n, d) at `t_min`.
    pub x0: Vec<f64>,
    /// States at every node `ts[0..=N]` (including the prior draw).
    pub xs: Vec<Vec<f64>>,
    /// Primary (post-hook) directions at `ts[0..N]`.
    pub ds: Vec<Vec<f64>>,
    /// Model evaluations actually spent.
    pub nfe: usize,
}

/// Run `solver` over `sched` starting from `x_t` (a batch of `n` rows drawn
/// from the prior `N(0, T^2 I)`).
///
/// Compatibility wrapper over [`engine::SamplerEngine`] with full
/// trajectory recording; one workspace is allocated per call. Long-lived
/// callers (the serving path, benches) should hold their own engine to
/// reuse the workspace across runs, and use [`engine::Record::None`] when
/// trajectories are not needed.
pub fn run_solver(
    solver: &dyn Solver,
    model: &dyn EpsModel,
    x_t: &[f64],
    n: usize,
    sched: &Schedule,
    hook: Option<&mut dyn DirectionHook>,
) -> SolveRun {
    engine::SamplerEngine::new(engine::EngineConfig::default())
        .run(solver, model, x_t, n, sched, hook)
}

/// The seed repo's allocate-per-step driver, kept as the reference
/// implementation: the engine parity tests assert the engine is
/// bit-identical to this, and `benches/solver_step.rs` reports the
/// speedup against it. The only structural change since the seed is a
/// one-shot [`StepScratch`] arena (the trait now requires one); the
/// sequential per-row arithmetic — and therefore every output bit — is
/// untouched, which is what keeps this the oracle.
pub fn run_solver_legacy(
    solver: &dyn Solver,
    model: &dyn EpsModel,
    x_t: &[f64],
    n: usize,
    sched: &Schedule,
    mut hook: Option<&mut dyn DirectionHook>,
) -> SolveRun {
    let dim = model.dim();
    assert_eq!(x_t.len(), n * dim);
    let n_steps = sched.n_steps();
    let mut xs: Vec<Vec<f64>> = Vec::with_capacity(n_steps + 1);
    let mut ds: Vec<Vec<f64>> = Vec::with_capacity(n_steps);
    xs.push(x_t.to_vec());
    let mut nfe = 0usize;
    let mut out = vec![0.0; n * dim];
    let mut scratch_buf = vec![0.0; solver.scratch_spec(dim, n).len_for(n)];
    for j in 0..n_steps {
        let t = sched.ts[j];
        let t_next = sched.ts[j + 1];
        // Primary evaluation.
        let mut d = vec![0.0; n * dim];
        model.eval_batch(&xs[j], n, t, &mut d);
        nfe += 1;
        let ctx = StepCtx {
            j,
            i_paper: n_steps - j,
            t,
            t_next,
            sched,
            xs: NodeView::nested(&xs),
            ds: NodeView::nested(&ds),
        };
        if let Some(h) = hook.as_deref_mut() {
            h.correct(&ctx, &xs[j], n, &mut d);
        }
        let mut scratch = StepScratch::new(&mut scratch_buf);
        solver.step(model, &ctx, &xs[j], &d, n, &mut out, &mut scratch);
        nfe += solver.evals_per_step() - 1; // internal evals
        ds.push(d);
        xs.push(out.clone());
    }
    SolveRun {
        x0: xs.last().unwrap().clone(),
        xs,
        ds,
        nfe,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry::get;
    use crate::schedule::default_schedule;
    use crate::score::analytic::AnalyticEps;
    use crate::score::counting::CountingEps;
    use crate::util::rng::Pcg64;

    #[test]
    fn driver_records_everything_and_counts_nfe() {
        let ds = get("gmm2d").unwrap();
        let m = AnalyticEps::from_dataset(&ds);
        let c = CountingEps::new(m.as_ref());
        let sched = default_schedule(6);
        let mut rng = Pcg64::seed(0);
        let n = 4;
        let x_t: Vec<f64> = rng.normal_vec(n * 2).iter().map(|z| z * 80.0).collect();
        let run = run_solver(&euler::Euler, &c, &x_t, n, &sched, None);
        assert_eq!(run.xs.len(), 7);
        assert_eq!(run.ds.len(), 6);
        assert_eq!(run.nfe, 6);
        assert_eq!(c.nfe(), 6);
        assert_eq!(run.x0, *run.xs.last().unwrap());
    }

    struct ZeroingHook;
    impl DirectionHook for ZeroingHook {
        fn correct(&mut self, _c: &StepCtx<'_>, _x: &[f64], _n: usize, d: &mut [f64]) -> bool {
            d.fill(0.0);
            true
        }
    }

    #[test]
    fn hook_can_freeze_the_trajectory() {
        let ds = get("gmm2d").unwrap();
        let m = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(4);
        let x_t = vec![5.0, 5.0];
        let mut hook = ZeroingHook;
        let run = run_solver(&euler::Euler, m.as_ref(), &x_t, 1, &sched, Some(&mut hook));
        assert_eq!(run.x0, x_t, "zeroed directions must freeze the state");
        // Corrected (zeroed) directions are what lands in the record.
        assert!(run.ds.iter().all(|d| d.iter().all(|&v| v == 0.0)));
    }

    #[test]
    fn step_scratch_carves_disjoint_buffers() {
        let spec = ScratchSpec { per_row: 3, flat: 2 };
        assert_eq!(spec.len_for(4), 14);
        let mut buf = vec![0.0; spec.len_for(4)];
        let mut s = StepScratch::new(&mut buf);
        let a = s.take(12);
        let b = s.take(2);
        a.fill(1.0);
        b.fill(2.0);
        assert_eq!(s.remaining(), 0);
        assert!(buf[..12].iter().all(|&v| v == 1.0));
        assert!(buf[12..].iter().all(|&v| v == 2.0));
    }

    #[test]
    #[should_panic(expected = "underprovisioned")]
    fn step_scratch_overdraw_panics() {
        let mut buf = vec![0.0; 4];
        let mut s = StepScratch::new(&mut buf);
        let _ = s.take(5);
    }

    #[test]
    fn node_view_nested_and_flat_agree() {
        let nested: Vec<Vec<f64>> = vec![vec![1.0, 2.0, 3.0, 4.0], vec![5.0, 6.0, 7.0, 8.0]];
        let flat: Vec<f64> = nested.iter().flatten().copied().collect();
        let a = NodeView::nested(&nested);
        let b = NodeView::flat(&flat, 4);
        assert_eq!(a.len(), 2);
        assert_eq!(b.len(), 2);
        for i in 0..2 {
            assert_eq!(a.row(i), b.row(i));
            assert_eq!(&a[i], &b[i]);
        }
        // Column sub-views (rows of 2 samples x dim 2, take sample 1).
        let ac = a.cols(2, 2);
        let bc = b.cols(2, 2);
        assert_eq!(ac.row(1), &[7.0, 8.0]);
        assert_eq!(bc.row(1), &[7.0, 8.0]);
    }
}
