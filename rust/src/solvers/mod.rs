//! ODE solvers for the EDM probability-flow ODE `dx/dt = eps(x, t)`.
//!
//! All solvers plug into one driver ([`run_solver`]) built around the
//! paper's uniform first-order-representable step (Eq. 16):
//!
//! ```text
//! x_{t_{i-1}} = phi(x_{t_i}, d_{t_i}, t_i, t_{i-1})
//! ```
//!
//! where `d_{t_i}` is the *primary* model evaluation of the step. The
//! driver evaluates `d`, offers it to an optional [`DirectionHook`]
//! (PAS's correction point, Algorithms 1–2), then lets the solver combine
//! it with history. Multistep solvers receive the corrected `d` in their
//! history exactly as Algorithm 1 line 17 requires.
//!
//! NFE accounting is explicit: `steps_for_nfe` refuses budgets the solver
//! cannot hit exactly (e.g. DPM-Solver-2 at odd NFE — the "\\" cells of the
//! paper's tables).

pub mod euler;
pub mod rk;
pub mod multistep;
pub mod dpmpp;
pub mod unipc;
pub mod registry;

use crate::schedule::Schedule;
use crate::score::EpsModel;

/// Per-step context handed to solvers and hooks.
pub struct StepCtx<'a> {
    /// 0-based step index: transition `ts[j] -> ts[j+1]`.
    pub j: usize,
    /// Paper-style index `i = N - j` (runs N..1).
    pub i_paper: usize,
    pub t: f64,
    pub t_next: f64,
    pub sched: &'a Schedule,
    /// States at nodes `ts[0..=j]` (so `xs[j]` is the current state).
    pub xs: &'a [Vec<f64>],
    /// Corrected primary directions at `ts[0..j]` (past steps only).
    pub ds: &'a [Vec<f64>],
}

impl StepCtx<'_> {
    /// Step size `t_next - t` (negative: time decreases).
    pub fn h(&self) -> f64 {
        self.t_next - self.t
    }

    /// Log-SNR half-step: `lambda = -ln t` in EDM.
    pub fn lambda(&self, t: f64) -> f64 {
        -t.ln()
    }
}

/// Hook invoked right after the primary model evaluation of each step.
/// PAS implements this; tests use it to inject faults.
pub trait DirectionHook {
    /// May modify `d` (the batch of primary directions, `(n, dim)`)
    /// in place. Returns true if a correction was applied.
    fn correct(&mut self, ctx: &StepCtx<'_>, x: &[f64], n: usize, d: &mut [f64]) -> bool;
}

/// A no-op hook.
pub struct NoHook;

impl DirectionHook for NoHook {
    fn correct(&mut self, _ctx: &StepCtx<'_>, _x: &[f64], _n: usize, _d: &mut [f64]) -> bool {
        false
    }
}

/// One deterministic ODE solver.
pub trait Solver: Send + Sync {
    fn name(&self) -> &str;

    /// Model evaluations consumed per step (1 unless noted).
    fn evals_per_step(&self) -> usize {
        1
    }

    /// Steps affordable with an exact NFE budget; `None` if the budget is
    /// not representable (paper's "\\" cells).
    fn steps_for_nfe(&self, nfe: usize) -> Option<usize> {
        let e = self.evals_per_step();
        if nfe == 0 || nfe % e != 0 {
            None
        } else {
            Some(nfe / e)
        }
    }

    /// `d x_next / d d_current` when the primary direction enters the
    /// update linearly with a scalar coefficient (required by PAS training
    /// to backpropagate to the coordinates without autodiff); `None` for
    /// solvers whose step is nonlinear in `d` (Heun, DPM-Solver-2) or that
    /// re-use `d` nonlinearly (UniPC corrector).
    fn gamma(&self, ctx: &StepCtx<'_>) -> Option<f64>;

    /// Advance the batch: write `x_{t_{j+1}}` into `out`.
    fn step(
        &self,
        model: &dyn EpsModel,
        ctx: &StepCtx<'_>,
        x: &[f64],
        d: &[f64],
        n: usize,
        out: &mut [f64],
    );
}

/// Result of a sampling run.
pub struct SolveRun {
    /// Final samples (n, d) at `t_min`.
    pub x0: Vec<f64>,
    /// States at every node `ts[0..=N]` (including the prior draw).
    pub xs: Vec<Vec<f64>>,
    /// Primary (post-hook) directions at `ts[0..N]`.
    pub ds: Vec<Vec<f64>>,
    /// Model evaluations actually spent.
    pub nfe: usize,
}

/// Run `solver` over `sched` starting from `x_t` (a batch of `n` rows drawn
/// from the prior `N(0, T^2 I)`).
pub fn run_solver(
    solver: &dyn Solver,
    model: &dyn EpsModel,
    x_t: &[f64],
    n: usize,
    sched: &Schedule,
    mut hook: Option<&mut dyn DirectionHook>,
) -> SolveRun {
    let dim = model.dim();
    assert_eq!(x_t.len(), n * dim);
    let n_steps = sched.n_steps();
    let mut xs: Vec<Vec<f64>> = Vec::with_capacity(n_steps + 1);
    let mut ds: Vec<Vec<f64>> = Vec::with_capacity(n_steps);
    xs.push(x_t.to_vec());
    let mut nfe = 0usize;
    let mut out = vec![0.0; n * dim];
    for j in 0..n_steps {
        let t = sched.ts[j];
        let t_next = sched.ts[j + 1];
        // Primary evaluation.
        let mut d = vec![0.0; n * dim];
        model.eval_batch(&xs[j], n, t, &mut d);
        nfe += 1;
        let ctx = StepCtx {
            j,
            i_paper: n_steps - j,
            t,
            t_next,
            sched,
            xs: &xs,
            ds: &ds,
        };
        if let Some(h) = hook.as_deref_mut() {
            h.correct(&ctx, &xs[j], n, &mut d);
        }
        solver.step(model, &ctx, &xs[j], &d, n, &mut out);
        nfe += solver.evals_per_step() - 1; // internal evals
        ds.push(d);
        xs.push(out.clone());
    }
    SolveRun {
        x0: xs.last().unwrap().clone(),
        xs,
        ds,
        nfe,
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry::get;
    use crate::schedule::default_schedule;
    use crate::score::analytic::AnalyticEps;
    use crate::score::counting::CountingEps;
    use crate::util::rng::Pcg64;

    #[test]
    fn driver_records_everything_and_counts_nfe() {
        let ds = get("gmm2d").unwrap();
        let m = AnalyticEps::from_dataset(&ds);
        let c = CountingEps::new(m.as_ref());
        let sched = default_schedule(6);
        let mut rng = Pcg64::seed(0);
        let n = 4;
        let x_t: Vec<f64> = rng.normal_vec(n * 2).iter().map(|z| z * 80.0).collect();
        let run = run_solver(&euler::Euler, &c, &x_t, n, &sched, None);
        assert_eq!(run.xs.len(), 7);
        assert_eq!(run.ds.len(), 6);
        assert_eq!(run.nfe, 6);
        assert_eq!(c.nfe(), 6);
        assert_eq!(run.x0, *run.xs.last().unwrap());
    }

    struct ZeroingHook;
    impl DirectionHook for ZeroingHook {
        fn correct(&mut self, _c: &StepCtx<'_>, _x: &[f64], _n: usize, d: &mut [f64]) -> bool {
            d.fill(0.0);
            true
        }
    }

    #[test]
    fn hook_can_freeze_the_trajectory() {
        let ds = get("gmm2d").unwrap();
        let m = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(4);
        let x_t = vec![5.0, 5.0];
        let mut hook = ZeroingHook;
        let run = run_solver(&euler::Euler, m.as_ref(), &x_t, 1, &sched, Some(&mut hook));
        assert_eq!(run.x0, x_t, "zeroed directions must freeze the state");
        // Corrected (zeroed) directions are what lands in the record.
        assert!(run.ds.iter().all(|d| d.iter().all(|&v| v == 0.0)));
    }
}
