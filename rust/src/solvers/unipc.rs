//! UniPC (Zhao et al. 2023): unified predictor–corrector, data-prediction
//! form with the `B2(h) = e^{hh} - 1` ("bh2") variant, specialized to EDM.
//!
//! Faithful port of the official `uni_pc.py` `multistep_uni_pc_bh_update`
//! restructured for this crate's driver: the primary model evaluation at
//! the current node (which the official code performs on the *predicted*
//! state — exactly what our driver hands us, since the previous step's
//! output was the prediction) is first used by **UniC** to re-correct the
//! current state over the previous transition, then **UniP** predicts the
//! next state. One model evaluation per step; the final prediction is not
//! corrected (no evaluation exists at t_min), matching common usage.

use super::{Solver, StepCtx};
use crate::linalg::solve_linear;
use crate::score::EpsModel;

pub struct UniPc {
    pub max_order: usize,
    name: String,
}

impl UniPc {
    pub fn new(max_order: usize) -> UniPc {
        assert!((1..=3).contains(&max_order));
        UniPc {
            max_order,
            name: format!("unipc{max_order}m"),
        }
    }
}

/// Data prediction at a recorded node.
fn m_at(ctx: &StepCtx<'_>, node: usize) -> Vec<f64> {
    let t = ctx.sched.ts[node];
    ctx.xs[node]
        .iter()
        .zip(ctx.ds[node].iter())
        .map(|(x, d)| x - t * d)
        .collect()
}

/// Build the (R, b) system of the bh update for `k` unknowns, where `rks`
/// holds the log-SNR ratio of each auxiliary node (older history nodes,
/// plus 1.0 for the corrector's new node). `hh = -h` (predict_x0 form).
fn rb_system(rks: &[f64], hh: f64) -> (Vec<f64>, Vec<f64>) {
    let k = rks.len();
    let mut r = vec![0.0; k * k];
    let mut b = vec![0.0; k];
    let b_h = hh.exp_m1(); // bh2 variant
    let mut h_phi_k = hh.exp_m1() / hh - 1.0;
    let mut factorial_i = 1.0;
    for i in 1..=k {
        for (c, &rk) in rks.iter().enumerate() {
            r[(i - 1) * k + c] = rk.powi(i as i32 - 1);
        }
        b[i - 1] = h_phi_k * factorial_i / b_h;
        factorial_i *= (i + 1) as f64;
        h_phi_k = h_phi_k / hh - 1.0 / factorial_i;
    }
    (r, b)
}

/// One bh-form transition from `x_s` at `t_s` to `t_t`, with anchor model
/// output `m0` (data prediction at `t_s`'s node), divided differences
/// `d1s[k] = (m_k - m0)/r_k` for auxiliary nodes, and their `rks`.
/// If `d1_new` is given (corrector), it is the un-divided `(m_t - m0)`
/// difference with implied rk = 1.0 appended.
#[allow(clippy::too_many_arguments)]
fn bh_transition(
    x_s: &[f64],
    t_s: f64,
    t_t: f64,
    m0: &[f64],
    rks_hist: &[f64],
    d1s_hist: &[Vec<f64>],
    d1_new: Option<&[f64]>,
    out: &mut [f64],
) {
    let h = (t_s / t_t).ln();
    let hh = -h;
    let ratio = t_t / t_s;
    let h_phi_1 = hh.exp_m1(); // = t_t/t_s − 1
    let b_h = hh.exp_m1();
    let mut rks: Vec<f64> = rks_hist.to_vec();
    if d1_new.is_some() {
        rks.push(1.0);
    }
    // x_t_ = ratio x_s − h_phi_1 m0  (alpha = 1)
    for i in 0..out.len() {
        out[i] = ratio * x_s[i] - h_phi_1 * m0[i];
    }
    if rks.is_empty() {
        return; // first-order predictor == DDIM-form update
    }
    let rhos = if rks.len() == 1 && d1_new.is_some() {
        vec![0.5] // official special case for order-1 corrector
    } else {
        let (mut r, mut b) = rb_system(&rks, hh);
        solve_linear(&mut r, &mut b, rks.len()).expect("bh system solvable");
        b
    };
    let n_hist = d1s_hist.len();
    for (k, d1) in d1s_hist.iter().enumerate() {
        let c = b_h * rhos[k];
        for i in 0..out.len() {
            out[i] -= c * d1[i];
        }
    }
    if let Some(dn) = d1_new {
        let c = b_h * rhos[n_hist];
        for i in 0..out.len() {
            out[i] -= c * dn[i];
        }
    }
}

impl Solver for UniPc {
    fn name(&self) -> &str {
        &self.name
    }

    fn gamma(&self, _ctx: &StepCtx<'_>) -> Option<f64> {
        None // current eval feeds both UniC and UniP; PAS targets DDIM/iPNDM
    }

    fn step(
        &self,
        _model: &dyn EpsModel,
        ctx: &StepCtx<'_>,
        x: &[f64],
        d: &[f64],
        _n: usize,
        out: &mut [f64],
    ) {
        let j = ctx.j;
        let t = ctx.t;
        let lam = |tt: f64| -f64::ln(tt);
        // Data prediction at the current node from the (possibly
        // PAS-corrected) primary direction.
        let m_t: Vec<f64> = x.iter().zip(d.iter()).map(|(xi, di)| xi - t * di).collect();

        // --- UniC: re-correct the current state over the previous
        // transition t_{j-1} -> t_j using the fresh model output. ---
        let mut x_cur = x.to_vec();
        if j >= 1 {
            let t_prev = ctx.sched.ts[j - 1];
            let m0 = m_at(ctx, j - 1);
            let h_prev = lam(t) - lam(t_prev);
            let order_c = self.max_order.min(j); // nodes at <= j-1
            let mut rks = Vec::new();
            let mut d1s: Vec<Vec<f64>> = Vec::new();
            for k in 1..order_c {
                let node = j - 1 - k;
                let rk = (lam(ctx.sched.ts[node]) - lam(t_prev)) / h_prev;
                let mk = m_at(ctx, node);
                d1s.push(
                    mk.iter()
                        .zip(m0.iter())
                        .map(|(a, b)| (a - b) / rk)
                        .collect(),
                );
                rks.push(rk);
            }
            let d1_new: Vec<f64> = m_t.iter().zip(m0.iter()).map(|(a, b)| a - b).collect();
            bh_transition(
                &ctx.xs[j - 1],
                t_prev,
                t,
                &m0,
                &rks,
                &d1s,
                Some(&d1_new),
                &mut x_cur,
            );
        }

        // --- UniP: predict the next state from the corrected current
        // state, anchored at m_t. ---
        let t_next = ctx.t_next;
        let h = lam(t_next) - lam(t);
        let order_p = self.max_order.min(j + 1);
        let mut rks = Vec::new();
        let mut d1s: Vec<Vec<f64>> = Vec::new();
        for k in 1..order_p {
            let node = j - k;
            let rk = (lam(ctx.sched.ts[node]) - lam(t)) / h;
            let mk = m_at(ctx, node);
            d1s.push(
                mk.iter()
                    .zip(m_t.iter())
                    .map(|(a, b)| (a - b) / rk)
                    .collect(),
            );
            rks.push(rk);
        }
        bh_transition(&x_cur, t, t_next, &m_t, &rks, &d1s, None, out);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Mode;
    use crate::schedule::Schedule;
    use crate::score::analytic::AnalyticEps;
    use crate::score::EpsModel;
    use crate::solvers::{euler::Euler, run_solver};

    struct LinearEps;
    impl EpsModel for LinearEps {
        fn dim(&self) -> usize {
            1
        }
        fn eval_batch(&self, x: &[f64], _n: usize, t: f64, out: &mut [f64]) {
            for i in 0..x.len() {
                out[i] = x[i] / t;
            }
        }
        fn name(&self) -> &str {
            "linear"
        }
    }

    /// Data prediction is identically zero for eps = x/t, so UniPC must be
    /// exact regardless of order.
    #[test]
    fn exact_on_pure_scaling_ode() {
        let sched = Schedule::polynomial(6, 0.5, 10.0, 7.0);
        let exact = 10.0 * 0.5 / 10.0;
        for ord in 1..=3 {
            let run = run_solver(&UniPc::new(ord), &LinearEps, &[10.0], 1, &sched, None);
            assert!(
                (run.x0[0] - exact).abs() < 1e-10,
                "order {ord}: {}",
                run.x0[0]
            );
        }
    }

    #[test]
    fn beats_euler_on_gaussian() {
        let m = AnalyticEps::new("g", vec![Mode::isotropic(vec![3.0], 0.5, 1.0, 0)]);
        let fine = Schedule::polynomial(400, 0.002, 80.0, 7.0);
        let reference = run_solver(&Euler, m.as_ref(), &[40.0], 1, &fine, None).x0[0];
        // 16 steps: past the multistep warm-up on the rho-7 grid.
        let sched = Schedule::polynomial(16, 0.002, 80.0, 7.0);
        let e_euler =
            (run_solver(&Euler, m.as_ref(), &[40.0], 1, &sched, None).x0[0] - reference).abs();
        let e_unipc =
            (run_solver(&UniPc::new(3), m.as_ref(), &[40.0], 1, &sched, None).x0[0] - reference)
                .abs();
        assert!(
            e_unipc < e_euler * 0.5,
            "unipc {e_unipc} vs euler {e_euler}"
        );
    }

    #[test]
    fn rb_system_first_row_is_ones() {
        let (r, b) = rb_system(&[-0.5, 1.0], -0.3);
        assert_eq!(r[0], 1.0);
        assert_eq!(r[1], 1.0);
        assert!(b[0].is_finite());
    }
}
