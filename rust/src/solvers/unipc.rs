//! UniPC (Zhao et al. 2023): unified predictor–corrector, data-prediction
//! form with the `B2(h) = e^{hh} - 1` ("bh2") variant, specialized to EDM.
//!
//! Faithful port of the official `uni_pc.py` `multistep_uni_pc_bh_update`
//! restructured for this crate's driver: the primary model evaluation at
//! the current node (which the official code performs on the *predicted*
//! state — exactly what our driver hands us, since the previous step's
//! output was the prediction) is first used by **UniC** to re-correct the
//! current state over the previous transition, then **UniP** predicts the
//! next state. One model evaluation per step; the final prediction is not
//! corrected (no evaluation exists at t_min), matching common usage.
//!
//! All per-step temporaries (data predictions, divided differences, the
//! corrected state) are carved from the caller's [`StepScratch`] arena and
//! the order-k coefficient system lives in stack arrays, so `step`
//! performs **zero heap allocations** — `tests/alloc_audit.rs` enforces
//! this through the engine. A numerically singular auxiliary system
//! (coincident `rks`, impossible on real schedules but reachable through
//! direct calls) degrades gracefully to the first-order base update
//! instead of panicking.

use super::{ScratchSpec, Solver, StepCtx, StepScratch};
use crate::linalg::solve_linear;
use crate::score::EpsModel;

/// Max unknowns of the bh coefficient system the stack buffers support
/// (UniPC orders 1–3 need at most 3; tests exercise 4).
pub const MAX_K: usize = 4;

/// Coefficient magnitude beyond which the solved `rhos` are treated as a
/// numerically-singular artifact (legit schedules produce O(1) values).
const RHO_SANE_LIMIT: f64 = 1e8;

pub struct UniPc {
    /// Private so the `new` invariant (1..=3, strictly below [`MAX_K`])
    /// that sizes the stack buffers and the scratch spec cannot be
    /// bypassed after construction.
    max_order: usize,
    name: String,
}

impl UniPc {
    pub fn new(max_order: usize) -> UniPc {
        assert!((1..=3).contains(&max_order));
        UniPc {
            max_order,
            name: format!("unipc{max_order}m"),
        }
    }
}

/// Data prediction at a recorded node, into the scratch-carved `out`.
fn m_at_into(ctx: &StepCtx<'_>, node: usize, out: &mut [f64]) {
    let t = ctx.sched.ts[node];
    let x = &ctx.xs[node];
    let d = &ctx.ds[node];
    for i in 0..out.len() {
        out[i] = x[i] - t * d[i];
    }
}

/// Build the (R, b) system of the bh update for `k = rks.len()` unknowns,
/// where `rks` holds the log-SNR ratio of each auxiliary node (older
/// history nodes, plus 1.0 for the corrector's new node). `hh = -h`
/// (predict_x0 form). Heap-allocating variant kept for tests; the solver
/// hot path uses [`rb_system_solve`], whose arithmetic is identical.
pub fn rb_system(rks: &[f64], hh: f64) -> (Vec<f64>, Vec<f64>) {
    let k = rks.len();
    let mut r = vec![0.0; k * k];
    let mut b = vec![0.0; k];
    fill_rb(rks, hh, &mut r, &mut b);
    (r, b)
}

/// Shared (R, b) construction: R is the k×k row-major system, b the rhs.
fn fill_rb(rks: &[f64], hh: f64, r: &mut [f64], b: &mut [f64]) {
    let k = rks.len();
    let b_h = hh.exp_m1(); // bh2 variant
    let mut h_phi_k = hh.exp_m1() / hh - 1.0;
    let mut factorial_i = 1.0;
    for i in 1..=k {
        for (c, &rk) in rks.iter().enumerate() {
            r[(i - 1) * k + c] = rk.powi(i as i32 - 1);
        }
        b[i - 1] = h_phi_k * factorial_i / b_h;
        factorial_i *= (i + 1) as f64;
        h_phi_k = h_phi_k / hh - 1.0 / factorial_i;
    }
}

/// Solve the bh system into `rhos[..k]` using stack temporaries only.
/// Returns false when the system is numerically singular (exactly
/// coincident `rks`) or the solution is wild enough to be a singularity
/// artifact — callers degrade to the first-order base update.
fn rb_system_solve(rks: &[f64], hh: f64, rhos: &mut [f64; MAX_K]) -> bool {
    let k = rks.len();
    debug_assert!(k <= MAX_K);
    let mut r = [0.0f64; MAX_K * MAX_K];
    fill_rb(rks, hh, &mut r[..k * k], &mut rhos[..k]);
    if solve_linear(&mut r[..k * k], &mut rhos[..k], k).is_err() {
        return false;
    }
    rhos[..k]
        .iter()
        .all(|v| v.is_finite() && v.abs() <= RHO_SANE_LIMIT)
}

/// One bh-form transition from `x_s` at `t_s` to `t_t`, with anchor model
/// output `m0` (data prediction at `t_s`'s node), divided differences
/// `d1s_hist[k] = (m_k - m0)/r_k` for auxiliary nodes, and their `rks`.
/// If `d1_new` is given (corrector), it is the un-divided `(m_t - m0)`
/// difference with implied rk = 1.0 appended. Allocation-free.
#[allow(clippy::too_many_arguments)]
fn bh_transition(
    x_s: &[f64],
    t_s: f64,
    t_t: f64,
    m0: &[f64],
    rks_hist: &[f64],
    d1s_hist: &[&[f64]],
    d1_new: Option<&[f64]>,
    out: &mut [f64],
) {
    let h = (t_s / t_t).ln();
    let hh = -h;
    let ratio = t_t / t_s;
    let h_phi_1 = hh.exp_m1(); // = t_t/t_s − 1
    let b_h = hh.exp_m1();
    let n_hist = rks_hist.len();
    debug_assert_eq!(d1s_hist.len(), n_hist);
    let mut rks = [0.0f64; MAX_K];
    rks[..n_hist].copy_from_slice(rks_hist);
    let mut k = n_hist;
    if d1_new.is_some() {
        rks[k] = 1.0;
        k += 1;
    }
    // x_t_ = ratio x_s − h_phi_1 m0  (alpha = 1)
    for i in 0..out.len() {
        out[i] = ratio * x_s[i] - h_phi_1 * m0[i];
    }
    if k == 0 {
        return; // first-order predictor == DDIM-form update
    }
    let mut rhos = [0.0f64; MAX_K];
    if k == 1 && d1_new.is_some() {
        rhos[0] = 0.5; // official special case for order-1 corrector
    } else if !rb_system_solve(&rks[..k], hh, &mut rhos) {
        return; // graceful degradation: keep the base update
    }
    for (kk, d1) in d1s_hist.iter().enumerate() {
        let c = b_h * rhos[kk];
        for i in 0..out.len() {
            out[i] -= c * d1[i];
        }
    }
    if let Some(dn) = d1_new {
        let c = b_h * rhos[n_hist];
        for i in 0..out.len() {
            out[i] -= c * dn[i];
        }
    }
}

impl Solver for UniPc {
    fn name(&self) -> &str {
        &self.name
    }

    fn gamma(&self, _ctx: &StepCtx<'_>) -> Option<f64> {
        None // current eval feeds both UniC and UniP; PAS targets DDIM/iPNDM
    }

    fn hist_depth(&self) -> usize {
        // Deepest read: the UniC corrector's m_at_into touches xs/ds at
        // node j - 1 - k for k < order_c ≤ max_order, i.e. max_order
        // steps back (one deeper than the predictor's window).
        self.max_order
    }

    fn scratch_spec(&self, dim: usize, _n: usize) -> ScratchSpec {
        // m_t, x_cur, m0, mk_tmp, d1_new, plus (max_order - 1) divided-
        // difference rows (reused between corrector and predictor).
        ScratchSpec {
            per_row: (4 + self.max_order) * dim,
            flat: 0,
        }
    }

    fn step(
        &self,
        _model: &dyn EpsModel,
        ctx: &StepCtx<'_>,
        x: &[f64],
        d: &[f64],
        _n: usize,
        out: &mut [f64],
        scratch: &mut StepScratch<'_>,
    ) {
        let l = x.len();
        let j = ctx.j;
        let t = ctx.t;
        let lam = |tt: f64| -f64::ln(tt);
        // Data prediction at the current node from the (possibly
        // PAS-corrected) primary direction.
        let m_t = scratch.take(l);
        for i in 0..l {
            m_t[i] = x[i] - t * d[i];
        }
        let x_cur = scratch.take(l);
        x_cur.copy_from_slice(x);
        let m0 = scratch.take(l);
        let mk_tmp = scratch.take(l);
        let d1_new = scratch.take(l);
        let d1_block = scratch.take((self.max_order - 1) * l);

        // --- UniC: re-correct the current state over the previous
        // transition t_{j-1} -> t_j using the fresh model output. ---
        if j >= 1 {
            let t_prev = ctx.sched.ts[j - 1];
            m_at_into(ctx, j - 1, m0);
            let h_prev = lam(t) - lam(t_prev);
            let order_c = self.max_order.min(j); // nodes at <= j-1
            let mut rks = [0.0f64; MAX_K];
            let mut n_hist = 0usize;
            for k in 1..order_c {
                let node = j - 1 - k;
                let rk = (lam(ctx.sched.ts[node]) - lam(t_prev)) / h_prev;
                m_at_into(ctx, node, mk_tmp);
                let seg = &mut d1_block[(k - 1) * l..k * l];
                for i in 0..l {
                    seg[i] = (mk_tmp[i] - m0[i]) / rk;
                }
                rks[n_hist] = rk;
                n_hist += 1;
            }
            for i in 0..l {
                d1_new[i] = m_t[i] - m0[i];
            }
            let mut d1_refs: [&[f64]; MAX_K] = [&[]; MAX_K];
            for (k, r) in d1_refs.iter_mut().enumerate().take(n_hist) {
                *r = &d1_block[k * l..(k + 1) * l];
            }
            bh_transition(
                &ctx.xs[j - 1],
                t_prev,
                t,
                m0,
                &rks[..n_hist],
                &d1_refs[..n_hist],
                Some(&d1_new[..]),
                x_cur,
            );
        }

        // --- UniP: predict the next state from the corrected current
        // state, anchored at m_t. ---
        let t_next = ctx.t_next;
        let h = lam(t_next) - lam(t);
        let order_p = self.max_order.min(j + 1);
        let mut rks = [0.0f64; MAX_K];
        let mut n_hist = 0usize;
        for k in 1..order_p {
            let node = j - k;
            let rk = (lam(ctx.sched.ts[node]) - lam(t)) / h;
            m_at_into(ctx, node, mk_tmp);
            let seg = &mut d1_block[(k - 1) * l..k * l];
            for i in 0..l {
                seg[i] = (mk_tmp[i] - m_t[i]) / rk;
            }
            rks[n_hist] = rk;
            n_hist += 1;
        }
        let mut d1_refs: [&[f64]; MAX_K] = [&[]; MAX_K];
        for (k, r) in d1_refs.iter_mut().enumerate().take(n_hist) {
            *r = &d1_block[k * l..(k + 1) * l];
        }
        bh_transition(
            x_cur,
            t,
            t_next,
            m_t,
            &rks[..n_hist],
            &d1_refs[..n_hist],
            None,
            out,
        );
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::Mode;
    use crate::schedule::Schedule;
    use crate::score::analytic::AnalyticEps;
    use crate::score::EpsModel;
    use crate::solvers::{euler::Euler, run_solver};
    use crate::util::rng::Pcg64;

    struct LinearEps;
    impl EpsModel for LinearEps {
        fn dim(&self) -> usize {
            1
        }
        fn eval_batch(&self, x: &[f64], _n: usize, t: f64, out: &mut [f64]) {
            for i in 0..x.len() {
                out[i] = x[i] / t;
            }
        }
        fn name(&self) -> &str {
            "linear"
        }
    }

    /// Data prediction is identically zero for eps = x/t, so UniPC must be
    /// exact regardless of order.
    #[test]
    fn exact_on_pure_scaling_ode() {
        let sched = Schedule::polynomial(6, 0.5, 10.0, 7.0);
        let exact = 10.0 * 0.5 / 10.0;
        for ord in 1..=3 {
            let run = run_solver(&UniPc::new(ord), &LinearEps, &[10.0], 1, &sched, None);
            assert!(
                (run.x0[0] - exact).abs() < 1e-10,
                "order {ord}: {}",
                run.x0[0]
            );
        }
    }

    #[test]
    fn beats_euler_on_gaussian() {
        let m = AnalyticEps::new("g", vec![Mode::isotropic(vec![3.0], 0.5, 1.0, 0)]);
        let fine = Schedule::polynomial(400, 0.002, 80.0, 7.0);
        let reference = run_solver(&Euler, m.as_ref(), &[40.0], 1, &fine, None).x0[0];
        // 16 steps: past the multistep warm-up on the rho-7 grid.
        let sched = Schedule::polynomial(16, 0.002, 80.0, 7.0);
        let e_euler =
            (run_solver(&Euler, m.as_ref(), &[40.0], 1, &sched, None).x0[0] - reference).abs();
        let e_unipc =
            (run_solver(&UniPc::new(3), m.as_ref(), &[40.0], 1, &sched, None).x0[0] - reference)
                .abs();
        assert!(
            e_unipc < e_euler * 0.5,
            "unipc {e_unipc} vs euler {e_euler}"
        );
    }

    #[test]
    fn rb_system_first_row_is_ones() {
        let (r, b) = rb_system(&[-0.5, 1.0], -0.3);
        assert_eq!(r[0], 1.0);
        assert_eq!(r[1], 1.0);
        assert!(b[0].is_finite());
    }

    /// phi_k(h) = Σ_{j≥0} h^j / (j+k)! by direct Taylor summation — an
    /// independent construction of the quantities the bh recurrence
    /// produces (converges fast for the |h| ≤ 3 this test uses).
    fn phi_series(k: usize, h: f64) -> f64 {
        let mut term = 1.0f64;
        for f in 1..=k {
            term /= f as f64; // 1/k!
        }
        let mut sum = term;
        for j in 1..60 {
            term *= h / (j + k) as f64;
            sum += term;
        }
        sum
    }

    /// Property (satellite): the order-k coefficient system agrees with
    /// direct construction for k ≤ 4 — R is the Vandermonde matrix in the
    /// rks, b matches the Taylor-series phi functions, the stack-array
    /// solve path is bit-identical to the heap path, and the solved rhos
    /// satisfy the system.
    #[test]
    fn prop_rb_system_agrees_with_direct_construction() {
        let mut rng = Pcg64::seed(11);
        for trial in 0..200 {
            let k = 1 + rng.below(MAX_K); // 1..=4
            let hh = -(0.05 + 2.5 * rng.uniform());
            // Well-separated rks, mixing the signs real schedules produce.
            let mut rks = vec![0.0f64; k];
            for (c, rk) in rks.iter_mut().enumerate() {
                let sign = if rng.below(2) == 0 { 1.0 } else { -1.0 };
                *rk = sign * (0.3 + c as f64 + rng.uniform() * 0.4);
            }
            let (r, b) = rb_system(&rks, hh);
            // R: direct Vandermonde construction.
            for i in 0..k {
                for c in 0..k {
                    let want = rks[c].powi(i as i32);
                    assert_eq!(
                        r[i * k + c].to_bits(),
                        want.to_bits(),
                        "trial {trial}: R[{i}][{c}]"
                    );
                }
            }
            // b[i-1] = hh * phi_{i+1}(hh) * i! / expm1(hh), via the
            // independent series construction.
            let b_h = hh.exp_m1();
            let mut factorial = 1.0f64;
            for i in 1..=k {
                factorial *= i as f64;
                let want = hh * phi_series(i + 1, hh) * factorial / b_h;
                assert!(
                    (b[i - 1] - want).abs() < 1e-8 * (1.0 + want.abs()),
                    "trial {trial}: b[{}] = {} vs series {want}",
                    i - 1,
                    b[i - 1]
                );
            }
            // Stack solve path: same system, and the solution actually
            // satisfies it.
            let mut rhos = [0.0f64; MAX_K];
            assert!(
                rb_system_solve(&rks, hh, &mut rhos),
                "trial {trial}: well-separated rks must solve"
            );
            for i in 0..k {
                let lhs: f64 = (0..k).map(|c| r[i * k + c] * rhos[c]).sum();
                assert!(
                    (lhs - b[i]).abs() < 1e-7 * (1.0 + b[i].abs()),
                    "trial {trial}: residual row {i}: {lhs} vs {}",
                    b[i]
                );
            }
        }
    }

    /// Property (satellite): coincident or near-coincident `rks` make the
    /// Vandermonde system singular; the transition must degrade to the
    /// (always finite) first-order base update instead of panicking or
    /// emitting garbage.
    #[test]
    fn prop_near_singular_rks_degrade_gracefully() {
        let x_s = [1.0, -2.0];
        let m0 = [0.3, 0.1];
        let d1a = [0.5, -0.5];
        let d1b = [0.2, 0.4];
        let (t_s, t_t) = (2.0, 1.5);
        // Base (first-order) update for reference.
        let mut base = [0.0; 2];
        bh_transition(&x_s, t_s, t_t, &m0, &[], &[], None, &mut base);
        assert!(base.iter().all(|v| v.is_finite()));
        for perturb in [0.0, 1e-16, 1e-14, 1e-12] {
            let rks = [0.7, 0.7 * (1.0 + perturb)];
            let d1s: [&[f64]; 2] = [&d1a, &d1b];
            let mut out = [0.0; 2];
            bh_transition(&x_s, t_s, t_t, &m0, &rks, &d1s, None, &mut out);
            assert!(
                out.iter().all(|v| v.is_finite()),
                "perturb {perturb}: non-finite output {out:?}"
            );
            // Exactly singular (and singular-to-working-precision)
            // systems fall back to the base update bit-for-bit.
            if perturb == 0.0 {
                assert_eq!(out, base, "exactly singular must yield the base update");
            }
        }
        // Well-separated rks still apply the correction (sanity that the
        // degradation guard is not overeager).
        let rks = [0.7, -1.4];
        let d1s: [&[f64]; 2] = [&d1a, &d1b];
        let mut out = [0.0; 2];
        bh_transition(&x_s, t_s, t_t, &m0, &rks, &d1s, None, &mut out);
        assert!(out.iter().all(|v| v.is_finite()));
        assert_ne!(out, base, "distinct rks must correct away from base");
    }
}
