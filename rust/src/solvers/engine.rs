//! Zero-allocation parallel sampling engine.
//!
//! [`SamplerEngine`] replaces the seed's allocate-per-step driver
//! ([`super::run_solver_legacy`]) with a preallocated, reusable workspace:
//!
//! * **State ping-pong in place.** States and directions live in two
//!   [`NodeStore`]s — flat row-major buffers sized up front. The current
//!   state is read from the store while the next state is written into a
//!   disjoint slot of the *same* allocation, so a step performs no copy
//!   of the batch and no allocation at all.
//! * **[`Record`] policy.** `Record::Full` sizes the stores to the whole
//!   trajectory (`nfe + 1` state rows) for experiments and training;
//!   `Record::None` — the serving configuration — keeps only the trailing
//!   [`HIST_NODES`]-node ring the registered solvers (order ≤ 4) can
//!   reach, making memory O(batch) instead of O(batch × NFE). NFE
//!   accounting is identical in both modes.
//! * **Row-sharded stepping — for the whole registry.** When the solver
//!   reports [`Solver::row_independent`] and the batch is worth it, the
//!   update is sharded row-wise over the process pool
//!   ([`crate::util::pool::Pool`]); each shard sees a column sub-view of
//!   the history ([`NodeView::cols`]), so per-row f64 operation order is
//!   untouched and the output is **bit-identical** to the sequential
//!   legacy driver for every thread count — enforced by
//!   `tests/engine_parity.rs` across the whole solver registry. Multi-eval
//!   solvers (Heun, DPM-Solver-2) shard too: their internal model
//!   evaluations route through per-chunk `eval_batch` calls, which is
//!   bit-preserving whenever the model is row-independent
//!   ([`crate::score::EpsModel::rows_independent`]); models that key on
//!   absolute row indices opt out and step unsharded.
//! * **Scratch arenas.** Solver-internal temporaries (Heun's midpoint,
//!   DPM++'s data predictions, UniPC's divided differences) come from an
//!   engine-owned arena sized by [`Solver::scratch_spec`]; each parallel
//!   chunk gets its own disjoint [`StepScratch`] slice, so no solver
//!   allocates inside `step`.
//!
//! # Workspace lifecycle
//!
//! An engine is created once (per server worker, per bench, per
//! experiment loop) and reused: `reset` at the top of each run re-shapes
//! the stores and the scratch arena without shrinking their allocations,
//! so after the first run of a given shape the steady state performs
//! **zero heap allocations per step** for every registry solver in both
//! record modes — `tests/alloc_audit.rs` pins that with a counting global
//! allocator (as does `benches/pas_overhead.rs` for the serving
//! configuration). `run_into` writes the final samples into a
//! caller-provided buffer; `run` (Record::Full only) materializes a
//! legacy [`SolveRun`] for existing callers.

use super::{DirectionHook, NodeView, ScratchSpec, SolveRun, Solver, StepCtx, StepScratch};
use crate::schedule::Schedule;
use crate::score::EpsModel;
use crate::util::pool::{Pool, SendPtr};

/// Trajectory retention policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Record {
    /// Keep every state and direction row (experiments, training,
    /// [`SolveRun`] materialization).
    Full,
    /// Keep only the trailing solver-history ring; memory O(batch).
    None,
}

/// Engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    pub record: Record,
    /// Max row-shards for the solver update; `0` = pool size, `1` =
    /// sequential stepping. Output is bit-identical either way.
    pub threads: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            record: Record::Full,
            threads: 0,
        }
    }
}

/// Upper bound on history nodes retained in `Record::None` mode, and the
/// fixed depth of each [`SlotEngine`] slot's per-row ring. The deepest
/// look-back among registered solvers is 3 nodes behind the current one
/// (order-4 Adams–Bashforth, UniPC-3's corrector), i.e. 4 live nodes,
/// plus one slot that is always the in-flight write row — 6 leaves a
/// margin slot. Per-run retention is now sized from
/// [`Solver::hist_depth`] (clamped to `HIST_NODES - 2`), so this bound
/// only pays for itself when a solver actually declares the deepest
/// window; slot rings still use it because admission happens before the
/// serving key's solver is consulted.
pub const HIST_NODES: usize = 6;

/// Batches smaller than this (elements) step sequentially — sharding
/// overhead would dominate.
const MIN_SHARD_ELEMS: usize = 4096;

/// Preallocated flat row store with optional ring semantics: row `node`
/// lives in slot `node % cap_rows`. With `cap_rows >= total rows` it is a
/// plain dense matrix (Record::Full); smaller, it retains the trailing
/// window only (Record::None).
///
/// Besides backing the engine's state/direction workspaces, this is the
/// repo-wide flat `(node, n·dim)` trajectory container: the PAS trainer's
/// rollout state and [`crate::traj::GroundTruth`] store nodes here instead
/// of `Vec<Vec<f64>>`, reading them back through [`NodeView`]s.
pub struct NodeStore {
    data: Vec<f64>,
    row_len: usize,
    len: usize,
    cap_rows: usize,
}

impl NodeStore {
    // lint:allow(hot-path-alloc, empty constructor; reset() owns the one-time growth)
    pub fn new() -> NodeStore {
        NodeStore {
            data: Vec::new(),
            row_len: 0,
            len: 0,
            cap_rows: 0,
        }
    }

    /// Re-shape for a new run; never shrinks the allocation, so repeated
    /// runs of the same shape allocate nothing.
    pub fn reset(&mut self, row_len: usize, cap_rows: usize) {
        assert!(row_len > 0 && cap_rows > 0);
        self.row_len = row_len;
        self.cap_rows = cap_rows;
        self.len = 0;
        let need = row_len * cap_rows;
        if self.data.len() < need {
            self.data.resize(need, 0.0);
        }
    }

    /// Committed rows.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Committed row at absolute node index (panics if evicted).
    pub fn row(&self, node: usize) -> &[f64] {
        assert!(node < self.len, "node {node} not committed");
        assert!(
            node + self.cap_rows >= self.len,
            "node {node} evicted (len {}, cap {})",
            self.len,
            self.cap_rows
        );
        let slot = node % self.cap_rows;
        &self.data[slot * self.row_len..(slot + 1) * self.row_len]
    }

    /// Append one committed row (copying it into its slot).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.row_len);
        let slot = self.len % self.cap_rows;
        self.data[slot * self.row_len..(slot + 1) * self.row_len].copy_from_slice(row);
        self.len += 1;
    }

    /// Read-only [`NodeView`] over the committed rows. With
    /// `cap_rows >= len` (the dense configuration) every row is reachable;
    /// ring-backed stores only expose the retained trailing window.
    pub fn view(&self) -> NodeView<'_> {
        // A dense store has no in-flight write row, so the view's strict
        // eviction check (`node + cap_rows > len`) must admit every
        // committed row — same `+ 1` convention as [`NodeView::flat`].
        // Slot arithmetic is unaffected: dense rows live at slot == node.
        let cap = if self.cap_rows >= self.len {
            self.len + 1
        } else {
            self.cap_rows
        };
        NodeView::ring(self.data.as_ptr(), self.row_len, self.len, cap)
    }

    /// Split into (view of the committed rows, the uncommitted next-row
    /// slot). The view's retained window never includes the write slot
    /// (`NodeView` asserts `node + cap_rows > len`), which is what makes
    /// the aliasing sound.
    fn split_next(&mut self) -> (NodeView<'_>, &mut [f64]) {
        let slot = self.len % self.cap_rows;
        let base = self.data.as_mut_ptr();
        let view = NodeView::ring(base as *const f64, self.row_len, self.len, self.cap_rows);
        // SAFETY: `slot * row_len .. (slot + 1) * row_len` is in bounds
        // (slot < cap_rows) and disjoint from every row the view can
        // reach (see above).
        let row = unsafe {
            std::slice::from_raw_parts_mut(base.add(slot * self.row_len), self.row_len)
        };
        (view, row)
    }

    fn commit(&mut self) {
        self.len += 1;
    }

    /// Drop the backing allocation (used by [`SamplerEngine::run`] after
    /// materializing, so a one-shot run does not keep the flat trajectory
    /// resident alongside the nested copy). The next `reset` re-grows.
    // lint:allow(hot-path-alloc, deliberate deallocation of a one-shot run's workspace)
    fn release(&mut self) {
        self.data = Vec::new();
        self.len = 0;
    }

    /// Materialize nested rows (Record::Full stores only).
    // lint:allow(hot-path-alloc, one-shot materialization API; serving uses views)
    pub fn to_nested(&self) -> Vec<Vec<f64>> {
        assert!(
            self.cap_rows >= self.len,
            "ring store dropped rows; use Record::Full"
        );
        (0..self.len).map(|i| self.row(i).to_vec()).collect()
    }
}

impl Default for NodeStore {
    fn default() -> Self {
        NodeStore::new()
    }
}

/// The workspace-pooled sampling driver. See the module docs.
pub struct SamplerEngine {
    cfg: EngineConfig,
    xs: NodeStore,
    ds: NodeStore,
    /// Solver scratch arena ([`Solver::scratch_spec`]); sized in
    /// `run_into`, never shrunk, carved into per-chunk [`StepScratch`]
    /// slices by `step_rows`.
    scratch: Vec<f64>,
}

impl SamplerEngine {
    // lint:allow(hot-path-alloc, empty constructor; run_into sizes the workspaces once)
    pub fn new(cfg: EngineConfig) -> SamplerEngine {
        SamplerEngine {
            cfg,
            xs: NodeStore::new(),
            ds: NodeStore::new(),
            scratch: Vec::new(),
        }
    }

    /// Convenience constructor with auto thread sizing.
    pub fn with_record(record: Record) -> SamplerEngine {
        SamplerEngine::new(EngineConfig { record, threads: 0 })
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Recorded states (valid after a `Record::Full` [`Self::run_into`];
    /// [`Self::run`] releases the workspace after materializing).
    pub fn xs(&self) -> &NodeStore {
        &self.xs
    }

    /// Recorded directions (valid after a `Record::Full`
    /// [`Self::run_into`]; [`Self::run`] releases the workspace after
    /// materializing).
    pub fn ds(&self) -> &NodeStore {
        &self.ds
    }

    /// Run the solver, writing the final samples into `x0_out` (shape
    /// `(n, dim)` flat). Returns the NFE spent. This is the
    /// allocation-free serving entry point: with `Record::None` and a
    /// warmed workspace, no step allocates.
    pub fn run_into(
        &mut self,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        x_t: &[f64],
        n: usize,
        sched: &Schedule,
        mut hook: Option<&mut dyn DirectionHook>,
        x0_out: &mut [f64],
    ) -> usize {
        let dim = model.dim();
        assert_eq!(x_t.len(), n * dim, "x_t must be (n, dim) flat");
        assert_eq!(x0_out.len(), n * dim, "x0_out must be (n, dim) flat");
        let row_len = n * dim;
        let n_steps = sched.n_steps();
        let (xs_cap, ds_cap) = match self.cfg.record {
            Record::Full => (n_steps + 1, n_steps.max(1)),
            Record::None => {
                // Retain only the solver's declared lookback: at step j
                // it reads xs[j-depth..=j] (depth+1 live rows plus the
                // in-flight write row) and ds[j-depth..j] (depth rows
                // plus the write row). Clamped so an over-declaring
                // solver degrades to the historical full window.
                let depth = solver.hist_depth().min(HIST_NODES - 2);
                (
                    (n_steps + 1).min(depth + 2),
                    n_steps.max(1).min(depth + 1),
                )
            }
        };
        self.xs.reset(row_len, xs_cap);
        self.ds.reset(row_len, ds_cap);
        // Solver scratch arena: enough for the whole batch's per-row
        // temporaries plus one flat block per possible chunk (chunk count
        // never exceeds the shard cap). Never shrunk, so repeated runs of
        // the same shape allocate nothing.
        let spec = solver.scratch_spec(dim, n);
        let max_parts = if self.cfg.threads == 0 {
            Pool::global().size()
        } else {
            self.cfg.threads
        };
        let scratch_need = spec.per_row * n + spec.flat * max_parts.max(1);
        if self.scratch.len() < scratch_need {
            self.scratch.resize(scratch_need, 0.0);
        }
        self.xs.push_row(x_t);
        let mut nfe = 0usize;
        for j in 0..n_steps {
            let t = sched.ts[j];
            let t_next = sched.ts[j + 1];
            let (xs_view, x_next) = self.xs.split_next();
            let (ds_view, d) = self.ds.split_next();
            let x_cur = xs_view.row(j);
            // Primary evaluation, straight into the direction row.
            model.eval_batch(x_cur, n, t, d);
            nfe += 1;
            let ctx = StepCtx {
                j,
                i_paper: n_steps - j,
                t,
                t_next,
                sched,
                xs: xs_view,
                ds: ds_view,
            };
            if let Some(h) = hook.as_deref_mut() {
                h.correct(&ctx, x_cur, n, d);
            }
            step_rows(
                self.cfg.threads,
                solver,
                model,
                &ctx,
                x_cur,
                d,
                n,
                dim,
                spec,
                &mut self.scratch,
                x_next,
            );
            nfe += solver.evals_per_step() - 1; // internal evals
            self.ds.commit();
            self.xs.commit();
        }
        x0_out.copy_from_slice(self.xs.row(n_steps));
        nfe
    }

    /// Run and materialize a legacy [`SolveRun`] (requires
    /// `Record::Full`). Bit-identical to [`super::run_solver_legacy`].
    ///
    /// Materialization copies the flat workspace into nested rows
    /// (transiently ~2x the trajectory footprint); the workspace is
    /// released afterwards so only the [`SolveRun`] remains resident.
    /// Callers that want the zero-copy flat trajectory should use
    /// [`Self::run_into`] and read [`Self::xs`]/[`Self::ds`] instead.
    pub fn run(
        &mut self,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        x_t: &[f64],
        n: usize,
        sched: &Schedule,
        hook: Option<&mut dyn DirectionHook>,
    ) -> SolveRun {
        assert_eq!(
            self.cfg.record,
            Record::Full,
            "SolveRun materialization needs Record::Full; use run_into"
        );
        // lint:allow(hot-path-alloc, one-shot SolveRun materialization wrapper; serving goes through run_into)
        let mut x0 = vec![0.0; x_t.len()];
        let nfe = self.run_into(solver, model, x_t, n, sched, hook, &mut x0);
        let run = SolveRun {
            x0,
            xs: self.xs.to_nested(),
            ds: self.ds.to_nested(),
            nfe,
        };
        self.xs.release();
        self.ds.release();
        // lint:allow(hot-path-alloc, deliberate workspace drop after materializing)
        self.scratch = Vec::new();
        run
    }
}

/// One resident row of a [`SlotEngine`]: its own ring history (states and
/// directions, `row_len = dim`) whose committed length *is* the row's step
/// cursor — slot `xs` holds nodes `0..=j` after `j` steps.
struct Slot {
    xs: NodeStore,
    ds: NodeStore,
    active: bool,
}

/// Slot-resident engine for **step-level continuous batching**.
///
/// Where [`SamplerEngine`] drives one fixed batch from `t_max` to `t_min`,
/// a `SlotEngine` keeps a *changing population* of independent rows
/// resident across one shared [`Schedule`]:
///
/// * **Per-row step cursors.** Every slot carries its own position in the
///   schedule (the committed length of its state ring), so rows admitted
///   at different times coexist at different depths.
/// * **Slot admission / retirement.** [`Self::admit`] seeds free slots
///   with prior rows mid-flight (growing the slot table only when the
///   free list is empty); [`Self::retire_into`] copies a finished row's
///   final state out and returns the slot to the free list immediately —
///   no row ever waits for an unrelated row's rollout.
/// * **Per-slot ring history.** Each slot owns `HIST_NODES`-deep
///   [`NodeStore`] rings for states and directions, so multistep solvers'
///   lookback stays correct for rows at different depths. A step gathers
///   the cohort's admissible history window into ring-layout staging
///   buffers and hands solvers the same absolute-node [`NodeView`]s the
///   batch engine uses.
/// * **Sharded stepping over only-active slots.** [`Self::step_cohort`]
///   advances one *cohort* — rows sharing a cursor — through the same
///   [`step_rows`] dispatch as [`SamplerEngine`], so the whole solver
///   registry (multi-eval included) shards row-wise with per-chunk
///   scratch.
///
/// # Determinism contract
///
/// A row's samples are **bit-identical** to running that row alone
/// through [`SamplerEngine::run_into`], for every admission interleaving,
/// cohort composition, and thread count. This holds because per-row f64
/// operation order is composition-independent at every stage: the model
/// must report [`EpsModel::rows_independent`] (the blocked analytic eval
/// is bit-equal to `eval_one` per row regardless of batch makeup —
/// `tests/eval_blocked_parity.rs`), the solver must report
/// [`Solver::row_independent`] (chunk-layout invariance —
/// `tests/engine_parity.rs`), and history reads go through exact copies
/// of the row's own nodes. `server::service` tests enforce the end-to-end
/// claim under randomized mid-flight admission × thread caps {1, 4, 16}.
///
/// All buffers are grow-only: after a warm-up admission of a given shape,
/// steady-state stepping performs no heap allocation.
pub struct SlotEngine {
    /// Max row-shards for the solver update; `0` = pool size.
    threads: usize,
    dim: usize,
    n_steps: usize,
    slots: Vec<Slot>,
    /// Free slot ids (LIFO).
    free: Vec<usize>,
    n_active: usize,
    /// Ring-layout staging of the cohort's state history: node `m` lives
    /// at staging slot `m % (hist_depth + 2)`, each a flat `(rows, dim)`
    /// block — sized per tick from the stepping solver's
    /// [`Solver::hist_depth`], not the worst-case [`HIST_NODES`].
    xh_stage: Vec<f64>,
    /// Same for the direction history (committed nodes `< j` only),
    /// modulus `hist_depth + 1`.
    dh_stage: Vec<f64>,
    /// Cohort directions for the in-flight step.
    d_buf: Vec<f64>,
    /// Cohort next-state output.
    out_buf: Vec<f64>,
    /// Solver scratch arena (see [`Solver::scratch_spec`]).
    scratch: Vec<f64>,
    /// Cohort-relative indices of rows whose last step produced a
    /// non-finite direction or state (grow-only; cleared per step).
    poisoned: Vec<usize>,
}

impl SlotEngine {
    /// `threads` caps the row-shards per cohort step (`0` = pool size,
    /// `1` = sequential). Output bits are identical either way.
    // lint:allow(hot-path-alloc, empty constructor; admit/step grow the buffers once per shape)
    pub fn new(threads: usize) -> SlotEngine {
        SlotEngine {
            threads,
            dim: 0,
            n_steps: 0,
            slots: Vec::new(),
            free: Vec::new(),
            n_active: 0,
            xh_stage: Vec::new(),
            dh_stage: Vec::new(),
            d_buf: Vec::new(),
            out_buf: Vec::new(),
            scratch: Vec::new(),
            poisoned: Vec::new(),
        }
    }

    /// Re-shape for a new resident run (one compatibility key: fixed
    /// `dim` and schedule length). Never shrinks allocations; all slots
    /// return to the free list.
    pub fn reset(&mut self, dim: usize, n_steps: usize) {
        assert!(dim > 0 && n_steps > 0);
        self.dim = dim;
        self.n_steps = n_steps;
        self.n_active = 0;
        self.free.clear();
        self.poisoned.clear();
        for (i, s) in self.slots.iter_mut().enumerate() {
            s.active = false;
            self.free.push(i);
        }
    }

    /// Rows currently resident.
    pub fn active_rows(&self) -> usize {
        self.n_active
    }

    /// Schedule length this engine was reset for.
    pub fn n_steps(&self) -> usize {
        self.n_steps
    }

    /// Admit `x_t.len() / dim` rows at cursor 0, appending their slot ids
    /// to `slots_out` (in row order). Grows the slot table when the free
    /// list runs dry — callers enforce their own residency cap.
    pub fn admit(&mut self, x_t: &[f64], slots_out: &mut Vec<usize>) {
        let dim = self.dim;
        assert!(dim > 0, "reset the engine before admitting");
        assert!(!x_t.is_empty() && x_t.len() % dim == 0, "x_t must be (rows, dim) flat");
        let rows = x_t.len() / dim;
        for r in 0..rows {
            let id = match self.free.pop() {
                Some(id) => id,
                None => {
                    self.slots.push(Slot {
                        xs: NodeStore::new(),
                        ds: NodeStore::new(),
                        active: false,
                    });
                    self.slots.len() - 1
                }
            };
            let slot = &mut self.slots[id];
            // Slot rings keep the worst-case depth: admission happens
            // before the key's solver is known here, and a fixed shape
            // keeps re-admission into a freed slot allocation-free.
            // Only the per-tick staging gather is depth-trimmed.
            slot.xs.reset(dim, HIST_NODES);
            slot.ds.reset(dim, HIST_NODES);
            slot.xs.push_row(&x_t[r * dim..(r + 1) * dim]);
            slot.active = true;
            slots_out.push(id);
            self.n_active += 1;
        }
    }

    /// Step cursor of a resident slot (steps taken so far).
    pub fn cursor(&self, slot: usize) -> usize {
        assert!(self.slots[slot].active, "slot {slot} not resident");
        self.slots[slot].xs.len() - 1
    }

    /// Copy a finished row's final state (`(dim,)`) into `out` and free
    /// its slot.
    pub fn retire_into(&mut self, slot: usize, out: &mut [f64]) {
        let n_steps = self.n_steps;
        let s = &mut self.slots[slot];
        assert!(s.active, "slot {slot} not resident");
        assert_eq!(s.xs.len(), n_steps + 1, "slot {slot} has not finished its schedule");
        out.copy_from_slice(s.xs.row(n_steps));
        s.active = false;
        s.xs.reset(1, 1); // drop logical contents; allocation is retained
        s.ds.reset(1, 1);
        self.free.push(slot);
        self.n_active -= 1;
    }

    /// Free a resident slot *without* retiring it — the numeric-failure
    /// path: the row's state is poisoned (non-finite), so there is
    /// nothing to copy out. Unlike [`Self::retire_into`] the row may be
    /// at any cursor.
    pub fn evict(&mut self, slot: usize) {
        let s = &mut self.slots[slot];
        assert!(s.active, "slot {slot} not resident");
        s.active = false;
        s.xs.reset(1, 1);
        s.ds.reset(1, 1);
        self.free.push(slot);
        self.n_active -= 1;
    }

    /// Cohort-relative indices (into the `slots` argument of the last
    /// [`Self::step_cohort`] call) of rows whose step produced a
    /// non-finite direction or next state. Sorted ascending; empty on a
    /// clean step. Callers fail these rows individually ([`Self::evict`])
    /// — row independence means the scan never indicts neighbours.
    pub fn poisoned_rows(&self) -> &[usize] {
        &self.poisoned
    }

    /// Advance one cohort — resident rows sharing a step cursor — by one
    /// solver step. `slots` lists the cohort's slot ids in row order;
    /// every listed slot must be at the same cursor `j < n_steps`. The
    /// optional hook sees the gathered `(rows, dim)` batch exactly as a
    /// [`SamplerEngine`] hook would. Returns the model evaluations spent
    /// (`rows`-invariant: one logical NFE per eval, as everywhere else).
    pub fn step_cohort(
        &mut self,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        sched: &Schedule,
        slots: &[usize],
        mut hook: Option<&mut dyn DirectionHook>,
    ) -> usize {
        let rows = slots.len();
        assert!(rows > 0, "empty cohort");
        let dim = self.dim;
        assert_eq!(sched.n_steps(), self.n_steps, "schedule shape changed mid-run");
        let j = self.slots[slots[0]].xs.len() - 1;
        assert!(j < self.n_steps, "cohort already finished");
        let row_len = rows * dim;
        // Stage only the lookback window the solver declared: at step j
        // it reads xs[j-depth ..= j] and ds[j-depth .. j]
        // ([`Solver::hist_depth`]), so single-step solvers gather one
        // state node per tick instead of the full `HIST_NODES - 1`
        // window. The ring caps (and staging layout moduli — they must
        // match) are depth+2 for xs (depth+1 live rows + the in-flight
        // write slot of the ring convention) and depth+1 for ds (depth
        // live rows + write slot). Clamped so an over-declaring solver
        // degrades to the historical full window.
        let depth = solver.hist_depth().min(HIST_NODES - 2);
        let xw = depth + 2;
        let dw = depth + 1;
        if self.xh_stage.len() < xw * row_len {
            self.xh_stage.resize(xw * row_len, 0.0);
        }
        if self.dh_stage.len() < dw * row_len {
            self.dh_stage.resize(dw * row_len, 0.0);
        }
        if self.d_buf.len() < row_len {
            self.d_buf.resize(row_len, 0.0);
        }
        if self.out_buf.len() < row_len {
            self.out_buf.resize(row_len, 0.0);
        }
        // Gather the admissible history windows into ring-layout staging:
        // exactly the nodes a `NodeView::ring(len, xw)` admits, copied
        // from each slot's own (HIST_NODES-deep) ring — bit-exact reads
        // of the row's past. States: nodes `len - (xw - 1) ..= j` of
        // `len = j + 1`; directions: the trailing `dw - 1` of the `j`
        // committed. The x loop always runs at least once (the current
        // node), so the residency/cursor asserts hold at every depth.
        let x_lo = (j + 1).saturating_sub(xw - 1);
        for node in x_lo..=j {
            let base = (node % xw) * row_len;
            for (r, &id) in slots.iter().enumerate() {
                let s = &self.slots[id];
                assert!(s.active, "slot {id} not resident");
                assert_eq!(s.xs.len(), j + 1, "cohort slots must share a cursor");
                self.xh_stage[base + r * dim..base + (r + 1) * dim]
                    .copy_from_slice(s.xs.row(node));
            }
        }
        let d_lo = j.saturating_sub(dw - 1);
        for node in d_lo..j {
            let base = (node % dw) * row_len;
            for (r, &id) in slots.iter().enumerate() {
                self.dh_stage[base + r * dim..base + (r + 1) * dim]
                    .copy_from_slice(self.slots[id].ds.row(node));
            }
        }
        let t = sched.ts[j];
        let t_next = sched.ts[j + 1];
        let x_cur: &[f64] = {
            let base = (j % xw) * row_len;
            // Reborrow immutably for the rest of the step; staging is not
            // written again until the next call.
            &self.xh_stage[base..base + row_len]
        };
        let d = &mut self.d_buf[..row_len];
        // Primary evaluation, then the hook, exactly as `run_into`.
        model.eval_batch(x_cur, rows, t, d);
        let xs_view = NodeView::ring(self.xh_stage.as_ptr(), row_len, j + 1, xw);
        let ds_view = NodeView::ring(self.dh_stage.as_ptr(), row_len, j, dw);
        let ctx = StepCtx {
            j,
            i_paper: self.n_steps - j,
            t,
            t_next,
            sched,
            xs: xs_view,
            ds: ds_view,
        };
        if let Some(h) = hook.as_deref_mut() {
            h.correct(&ctx, x_cur, rows, d);
        }
        let spec = solver.scratch_spec(dim, rows);
        let max_parts = if self.threads == 0 {
            Pool::global().size()
        } else {
            self.threads
        };
        let scratch_need = spec.per_row * rows + spec.flat * max_parts.max(1);
        if self.scratch.len() < scratch_need {
            self.scratch.resize(scratch_need, 0.0);
        }
        step_rows(
            self.threads,
            solver,
            model,
            &ctx,
            x_cur,
            d,
            rows,
            dim,
            spec,
            &mut self.scratch,
            &mut self.out_buf[..row_len],
        );
        // Chaos site: corrupt one row of the stepped cohort at the armed
        // tick. Disarmed cost is one relaxed atomic load.
        if crate::util::failpoint::peek(crate::util::failpoint::ENGINE_NAN_TICK) == Some(j as u64)
        {
            crate::util::failpoint::take(crate::util::failpoint::ENGINE_NAN_TICK);
            self.out_buf[0] = f64::NAN;
        }
        // Numeric guardrail: flag rows whose direction or next state went
        // non-finite this step. A grow-only index buffer keeps the scan
        // inside the zero-allocation budget; per-row scanning (not
        // whole-slab) lets the caller fail only the poisoned rows.
        self.poisoned.clear();
        for r in 0..rows {
            let d_row = &self.d_buf[r * dim..(r + 1) * dim];
            let x_row = &self.out_buf[r * dim..(r + 1) * dim];
            if d_row.iter().any(|v| !v.is_finite()) || x_row.iter().any(|v| !v.is_finite()) {
                self.poisoned.push(r);
            }
        }
        // Scatter: the (post-hook) direction becomes node `j` of each
        // slot's d-ring, the stepped state node `j + 1` of its x-ring —
        // advancing the cursor. Poisoned rows scatter too (their slots
        // stay cursor-consistent) and are evicted by the caller.
        for (r, &id) in slots.iter().enumerate() {
            let s = &mut self.slots[id];
            s.ds.push_row(&self.d_buf[r * dim..(r + 1) * dim]);
            s.xs.push_row(&self.out_buf[r * dim..(r + 1) * dim]);
        }
        solver.evals_per_step()
    }
}

impl Default for SlotEngine {
    fn default() -> Self {
        SlotEngine::new(0)
    }
}

/// Advance the batch, sharding rows across the pool when profitable.
/// Each shard receives column sub-views of the history and its own
/// disjoint [`StepScratch`] slice of the engine arena, so per-row
/// computation is exactly the sequential one. Multi-eval solvers shard
/// too: their internal model evaluations become per-chunk `eval_batch`
/// calls, which is bit-preserving because (and only when) the model is
/// row-independent — the `rows_independent` guard below.
///
/// `pub(crate)` so the PAS [`crate::pas::train::TrainSession`] can drive
/// its gamma-path solver steps (affine base, uncorrected next state)
/// through exactly the same sharded dispatch as the engine.
#[allow(clippy::too_many_arguments)]
pub(crate) fn step_rows(
    threads: usize,
    solver: &dyn Solver,
    model: &dyn EpsModel,
    ctx: &StepCtx<'_>,
    x: &[f64],
    d: &[f64],
    n: usize,
    dim: usize,
    spec: ScratchSpec,
    scratch: &mut [f64],
    out: &mut [f64],
) {
    let pool = Pool::global();
    let max_parts = if threads == 0 { pool.size() } else { threads };
    // The partition is computed up front (via the same `Pool::partition`
    // the dispatch uses) so each chunk's scratch slice can be located by
    // arithmetic: chunk c covers rows [c*chunk, (c+1)*chunk) and its
    // scratch starts at per_row * c * chunk + flat * c.
    //
    // Multi-eval solvers route their internal model evaluations through
    // per-chunk `eval_batch` calls, so their chunks are floored at the
    // model's preferred eval tile ([`EpsModel::preferred_tile`]) — a
    // sub-tile chunk would waste the blocked eval pipeline's panel
    // amortization. Purely a throughput knob: results are bit-identical
    // for every chunk layout (engine parity tests).
    let min_rows = if solver.evals_per_step() > 1 {
        model.preferred_tile().max(1)
    } else {
        1
    };
    let (chunk, n_chunks) = pool.partition(n, max_parts, min_rows);
    if max_parts <= 1
        || !solver.row_independent()
        || (solver.evals_per_step() != 1 && !model.rows_independent())
        || n < 2
        || n * dim < MIN_SHARD_ELEMS
        || n_chunks <= 1
    {
        let mut s = StepScratch::new(&mut scratch[..spec.len_for(n)]);
        solver.step(model, ctx, x, d, n, out, &mut s);
        return;
    }
    debug_assert!(spec.per_row * n + spec.flat * n_chunks <= scratch.len());
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    let scratch_ptr = SendPtr::new(scratch.as_mut_ptr());
    pool.run(n_chunks, &|c| {
        let r0 = c * chunk;
        let r1 = ((c + 1) * chunk).min(n);
        let c0 = r0 * dim;
        let c1 = r1 * dim;
        let sub = StepCtx {
            j: ctx.j,
            i_paper: ctx.i_paper,
            t: ctx.t,
            t_next: ctx.t_next,
            sched: ctx.sched,
            xs: ctx.xs.cols(c0, c1 - c0),
            ds: ctx.ds.cols(c0, c1 - c0),
        };
        // SAFETY: pool chunk indices are distinct, so the row ranges —
        // and the scratch slices derived from them — are disjoint.
        let o = unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(c0), c1 - c0) };
        let s_off = spec.per_row * r0 + spec.flat * c;
        let s_len = spec.len_for(r1 - r0);
        let sbuf =
            unsafe { std::slice::from_raw_parts_mut(scratch_ptr.get().add(s_off), s_len) };
        let mut s = StepScratch::new(sbuf);
        solver.step(model, &sub, &x[c0..c1], &d[c0..c1], r1 - r0, o, &mut s);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry::get;
    use crate::schedule::default_schedule;
    use crate::score::analytic::AnalyticEps;
    use crate::score::counting::CountingEps;
    use crate::solvers::{registry, run_solver_legacy};
    use crate::traj::sample_prior;
    use crate::util::rng::Pcg64;

    #[test]
    fn full_record_matches_legacy_bitwise() {
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(8);
        let mut rng = Pcg64::seed(11);
        let n = 64;
        let x_t = sample_prior(&mut rng, n, 64, sched.t_max());
        let solver = registry::get("ddim").unwrap();
        let legacy = run_solver_legacy(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None);
        let mut eng = SamplerEngine::with_record(Record::Full);
        let run = eng.run(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None);
        assert_eq!(legacy.x0, run.x0);
        assert_eq!(legacy.xs, run.xs);
        assert_eq!(legacy.ds, run.ds);
        assert_eq!(legacy.nfe, run.nfe);
    }

    #[test]
    fn record_none_keeps_samples_and_nfe() {
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let counting = CountingEps::new(model.as_ref());
        let sched = default_schedule(10);
        let mut rng = Pcg64::seed(12);
        let n = 32;
        let x_t = sample_prior(&mut rng, n, 64, sched.t_max());
        let solver = registry::get("ipndm").unwrap();
        let legacy = run_solver_legacy(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None);
        let mut eng = SamplerEngine::with_record(Record::None);
        let mut x0 = vec![0.0; n * 64];
        let nfe = eng.run_into(solver.as_ref(), &counting, &x_t, n, &sched, None, &mut x0);
        assert_eq!(x0, legacy.x0);
        assert_eq!(nfe, 10);
        assert_eq!(counting.nfe(), 10);
    }

    #[test]
    fn workspace_reuse_across_runs_is_clean() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(6);
        let solver = registry::get("dpmpp3m").unwrap();
        let mut eng = SamplerEngine::with_record(Record::None);
        let mut rng = Pcg64::seed(13);
        for trial in 0..3 {
            let n = [8usize, 16, 8][trial];
            let x_t = sample_prior(&mut rng, n, 2, sched.t_max());
            let legacy =
                run_solver_legacy(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None);
            let mut x0 = vec![0.0; n * 2];
            eng.run_into(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None, &mut x0);
            assert_eq!(x0, legacy.x0, "trial {trial}");
        }
    }

    /// Multi-eval solvers (previously excluded from sharding) must be
    /// bit-identical to the legacy driver under sharded stepping, with
    /// sharding-invariant NFE accounting.
    #[test]
    fn multi_eval_solvers_shard_bitwise() {
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(6);
        let mut rng = Pcg64::seed(14);
        let n = 64;
        let x_t = sample_prior(&mut rng, n, 64, sched.t_max());
        for name in ["heun", "dpm2"] {
            let solver = registry::get(name).unwrap();
            let legacy =
                run_solver_legacy(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None);
            for threads in [2usize, 8] {
                let counting = CountingEps::new(model.as_ref());
                let mut eng = SamplerEngine::new(EngineConfig {
                    record: Record::None,
                    threads,
                });
                let mut x0 = vec![0.0; n * 64];
                let nfe =
                    eng.run_into(solver.as_ref(), &counting, &x_t, n, &sched, None, &mut x0);
                assert_eq!(legacy.x0, x0, "{name} sharded x0 (threads={threads})");
                assert_eq!(nfe, 12, "{name} logical NFE");
                assert_eq!(counting.nfe_rows(n), 12, "{name} row-accounted NFE");
            }
        }
    }

    /// A model that keys on absolute row indices reports
    /// `rows_independent() == false`; multi-eval solvers must then see
    /// only full-batch evaluations (no per-chunk internal calls).
    #[test]
    fn rows_dependent_model_keeps_multi_eval_unsharded() {
        struct FullBatchOnly<'a> {
            inner: &'a dyn crate::score::EpsModel,
            n_expect: usize,
        }
        impl crate::score::EpsModel for FullBatchOnly<'_> {
            fn dim(&self) -> usize {
                self.inner.dim()
            }
            fn rows_independent(&self) -> bool {
                false
            }
            fn eval_batch(&self, x: &[f64], n: usize, t: f64, out: &mut [f64]) {
                assert_eq!(n, self.n_expect, "rows-dependent model saw a chunk");
                self.inner.eval_batch(x, n, t, out);
            }
            fn name(&self) -> &str {
                "full-batch-only"
            }
        }
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(4);
        let mut rng = Pcg64::seed(15);
        let n = 64; // n * dim = 4096: sharding would otherwise engage
        let x_t = sample_prior(&mut rng, n, 64, sched.t_max());
        let guard = FullBatchOnly {
            inner: model.as_ref(),
            n_expect: n,
        };
        let solver = registry::get("heun").unwrap();
        let mut eng = SamplerEngine::new(EngineConfig {
            record: Record::None,
            threads: 8,
        });
        let mut x0 = vec![0.0; n * 64];
        let nfe = eng.run_into(solver.as_ref(), &guard, &x_t, n, &sched, None, &mut x0);
        assert_eq!(nfe, 8);
    }

    /// Slot-resident stepping with staggered admissions (including
    /// re-admission into freed slots) must reproduce every request's solo
    /// run bit-for-bit, for single- and multi-step and multi-eval solvers.
    #[test]
    fn slot_engine_matches_solo_runs_under_staggered_admission() {
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let n_steps = 8;
        let sched = default_schedule(n_steps);
        let dim = 64;
        // (admission tick, rows): the third admission lands after the
        // first retired, so it reuses freed slots mid-flight.
        let arrivals: [(usize, usize); 3] = [(0, 3), (2, 2), (8, 4)];
        for name in ["ddim", "ipndm", "ipndm4", "dpmpp3m", "unipc3m", "deis-tab3", "heun"] {
            let solver = registry::get(name).unwrap();
            let mut rng = Pcg64::seed(21);
            let priors: Vec<Vec<f64>> = arrivals
                .iter()
                .map(|&(_, rows)| sample_prior(&mut rng, rows, dim, sched.t_max()))
                .collect();
            for threads in [1usize, 3] {
                let counting = CountingEps::new(model.as_ref());
                let mut eng = SlotEngine::new(threads);
                eng.reset(dim, n_steps);
                // (slots, cursor, arrival index) per live cohort.
                let mut live: Vec<(Vec<usize>, usize, usize)> = Vec::new();
                let mut done: Vec<(usize, Vec<f64>)> = Vec::new();
                let mut tick = 0usize;
                while done.len() < arrivals.len() {
                    for (a, &(at, _)) in arrivals.iter().enumerate() {
                        if at == tick {
                            let mut slots = Vec::new();
                            eng.admit(&priors[a], &mut slots);
                            live.push((slots, 0, a));
                        }
                    }
                    for (slots, cursor, _) in live.iter_mut() {
                        eng.step_cohort(solver.as_ref(), &counting, &sched, slots, None);
                        *cursor += 1;
                    }
                    live.retain_mut(|(slots, cursor, a)| {
                        if *cursor < n_steps {
                            return true;
                        }
                        let mut out = vec![0.0; slots.len() * dim];
                        for (r, &s) in slots.iter().enumerate() {
                            eng.retire_into(s, &mut out[r * dim..(r + 1) * dim]);
                        }
                        done.push((*a, out));
                        false
                    });
                    tick += 1;
                    assert!(tick < 64, "{name}: scheduler failed to drain");
                }
                for (a, got) in done {
                    let rows = arrivals[a].1;
                    let mut solo_eng = SamplerEngine::with_record(Record::None);
                    let mut want = vec![0.0; rows * dim];
                    solo_eng.run_into(
                        solver.as_ref(),
                        model.as_ref(),
                        &priors[a],
                        rows,
                        &sched,
                        None,
                        &mut want,
                    );
                    assert_eq!(
                        got, want,
                        "{name}: request {a} (threads={threads}) diverged from its solo run"
                    );
                }
                assert_eq!(eng.active_rows(), 0);
                // Per-slot NFE accounting: every resident row is evaluated
                // exactly `evals_per_step` times per step, regardless of
                // cohort composition or sharding.
                let total_rows: usize = arrivals.iter().map(|&(_, r)| r).sum();
                assert_eq!(
                    counting.rows_evaluated(),
                    total_rows * n_steps * solver.evals_per_step(),
                    "{name}: per-slot NFE accounting (threads={threads})"
                );
            }
        }
    }

    #[test]
    #[should_panic(expected = "share a cursor")]
    fn slot_engine_rejects_mixed_cursor_cohorts() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(4);
        let solver = registry::get("ddim").unwrap();
        let mut rng = Pcg64::seed(22);
        let mut eng = SlotEngine::new(1);
        eng.reset(2, 4);
        let (mut a, mut b) = (Vec::new(), Vec::new());
        eng.admit(&sample_prior(&mut rng, 1, 2, sched.t_max()), &mut a);
        eng.step_cohort(solver.as_ref(), model.as_ref(), &sched, &a, None);
        eng.admit(&sample_prior(&mut rng, 1, 2, sched.t_max()), &mut b);
        let mixed = vec![a[0], b[0]];
        let _ = eng.step_cohort(solver.as_ref(), model.as_ref(), &sched, &mixed, None);
    }

    #[test]
    #[should_panic(expected = "Record::Full")]
    fn run_requires_full_record() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(3);
        let solver = registry::get("ddim").unwrap();
        let mut eng = SamplerEngine::with_record(Record::None);
        let _ = eng.run(solver.as_ref(), model.as_ref(), &[1.0, 1.0], 1, &sched, None);
    }
}
