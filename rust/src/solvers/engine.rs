//! Zero-allocation parallel sampling engine.
//!
//! [`SamplerEngine`] replaces the seed's allocate-per-step driver
//! ([`super::run_solver_legacy`]) with a preallocated, reusable workspace:
//!
//! * **State ping-pong in place.** States and directions live in two
//!   [`NodeStore`]s — flat row-major buffers sized up front. The current
//!   state is read from the store while the next state is written into a
//!   disjoint slot of the *same* allocation, so a step performs no copy
//!   of the batch and no allocation at all.
//! * **[`Record`] policy.** `Record::Full` sizes the stores to the whole
//!   trajectory (`nfe + 1` state rows) for experiments and training;
//!   `Record::None` — the serving configuration — keeps only the trailing
//!   [`HIST_NODES`]-node ring the registered solvers (order ≤ 4) can
//!   reach, making memory O(batch) instead of O(batch × NFE). NFE
//!   accounting is identical in both modes.
//! * **Row-sharded stepping — for the whole registry.** When the solver
//!   reports [`Solver::row_independent`] and the batch is worth it, the
//!   update is sharded row-wise over the process pool
//!   ([`crate::util::pool::Pool`]); each shard sees a column sub-view of
//!   the history ([`NodeView::cols`]), so per-row f64 operation order is
//!   untouched and the output is **bit-identical** to the sequential
//!   legacy driver for every thread count — enforced by
//!   `tests/engine_parity.rs` across the whole solver registry. Multi-eval
//!   solvers (Heun, DPM-Solver-2) shard too: their internal model
//!   evaluations route through per-chunk `eval_batch` calls, which is
//!   bit-preserving whenever the model is row-independent
//!   ([`crate::score::EpsModel::rows_independent`]); models that key on
//!   absolute row indices opt out and step unsharded.
//! * **Scratch arenas.** Solver-internal temporaries (Heun's midpoint,
//!   DPM++'s data predictions, UniPC's divided differences) come from an
//!   engine-owned arena sized by [`Solver::scratch_spec`]; each parallel
//!   chunk gets its own disjoint [`StepScratch`] slice, so no solver
//!   allocates inside `step`.
//!
//! # Workspace lifecycle
//!
//! An engine is created once (per server worker, per bench, per
//! experiment loop) and reused: `reset` at the top of each run re-shapes
//! the stores and the scratch arena without shrinking their allocations,
//! so after the first run of a given shape the steady state performs
//! **zero heap allocations per step** for every registry solver in both
//! record modes — `tests/alloc_audit.rs` pins that with a counting global
//! allocator (as does `benches/pas_overhead.rs` for the serving
//! configuration). `run_into` writes the final samples into a
//! caller-provided buffer; `run` (Record::Full only) materializes a
//! legacy [`SolveRun`] for existing callers.

use super::{DirectionHook, NodeView, ScratchSpec, SolveRun, Solver, StepCtx, StepScratch};
use crate::schedule::Schedule;
use crate::score::EpsModel;
use crate::util::pool::{Pool, SendPtr};

/// Trajectory retention policy.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Record {
    /// Keep every state and direction row (experiments, training,
    /// [`SolveRun`] materialization).
    Full,
    /// Keep only the trailing solver-history ring; memory O(batch).
    None,
}

/// Engine configuration.
#[derive(Clone, Copy, Debug)]
pub struct EngineConfig {
    pub record: Record,
    /// Max row-shards for the solver update; `0` = pool size, `1` =
    /// sequential stepping. Output is bit-identical either way.
    pub threads: usize,
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            record: Record::Full,
            threads: 0,
        }
    }
}

/// History nodes retained in `Record::None` mode. The deepest look-back
/// among registered solvers is 3 nodes behind the current one (order-4
/// Adams–Bashforth, UniPC-3's corrector), i.e. 4 live nodes, plus one
/// slot that is always the in-flight write row — 6 leaves a margin slot.
pub const HIST_NODES: usize = 6;

/// Batches smaller than this (elements) step sequentially — sharding
/// overhead would dominate.
const MIN_SHARD_ELEMS: usize = 4096;

/// Preallocated flat row store with optional ring semantics: row `node`
/// lives in slot `node % cap_rows`. With `cap_rows >= total rows` it is a
/// plain dense matrix (Record::Full); smaller, it retains the trailing
/// window only (Record::None).
///
/// Besides backing the engine's state/direction workspaces, this is the
/// repo-wide flat `(node, n·dim)` trajectory container: the PAS trainer's
/// rollout state and [`crate::traj::GroundTruth`] store nodes here instead
/// of `Vec<Vec<f64>>`, reading them back through [`NodeView`]s.
pub struct NodeStore {
    data: Vec<f64>,
    row_len: usize,
    len: usize,
    cap_rows: usize,
}

impl NodeStore {
    pub fn new() -> NodeStore {
        NodeStore {
            data: Vec::new(),
            row_len: 0,
            len: 0,
            cap_rows: 0,
        }
    }

    /// Re-shape for a new run; never shrinks the allocation, so repeated
    /// runs of the same shape allocate nothing.
    pub fn reset(&mut self, row_len: usize, cap_rows: usize) {
        assert!(row_len > 0 && cap_rows > 0);
        self.row_len = row_len;
        self.cap_rows = cap_rows;
        self.len = 0;
        let need = row_len * cap_rows;
        if self.data.len() < need {
            self.data.resize(need, 0.0);
        }
    }

    /// Committed rows.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Committed row at absolute node index (panics if evicted).
    pub fn row(&self, node: usize) -> &[f64] {
        assert!(node < self.len, "node {node} not committed");
        assert!(
            node + self.cap_rows >= self.len,
            "node {node} evicted (len {}, cap {})",
            self.len,
            self.cap_rows
        );
        let slot = node % self.cap_rows;
        &self.data[slot * self.row_len..(slot + 1) * self.row_len]
    }

    /// Append one committed row (copying it into its slot).
    pub fn push_row(&mut self, row: &[f64]) {
        assert_eq!(row.len(), self.row_len);
        let slot = self.len % self.cap_rows;
        self.data[slot * self.row_len..(slot + 1) * self.row_len].copy_from_slice(row);
        self.len += 1;
    }

    /// Read-only [`NodeView`] over the committed rows. With
    /// `cap_rows >= len` (the dense configuration) every row is reachable;
    /// ring-backed stores only expose the retained trailing window.
    pub fn view(&self) -> NodeView<'_> {
        // A dense store has no in-flight write row, so the view's strict
        // eviction check (`node + cap_rows > len`) must admit every
        // committed row — same `+ 1` convention as [`NodeView::flat`].
        // Slot arithmetic is unaffected: dense rows live at slot == node.
        let cap = if self.cap_rows >= self.len {
            self.len + 1
        } else {
            self.cap_rows
        };
        NodeView::ring(self.data.as_ptr(), self.row_len, self.len, cap)
    }

    /// Split into (view of the committed rows, the uncommitted next-row
    /// slot). The view's retained window never includes the write slot
    /// (`NodeView` asserts `node + cap_rows > len`), which is what makes
    /// the aliasing sound.
    fn split_next(&mut self) -> (NodeView<'_>, &mut [f64]) {
        let slot = self.len % self.cap_rows;
        let base = self.data.as_mut_ptr();
        let view = NodeView::ring(base as *const f64, self.row_len, self.len, self.cap_rows);
        // SAFETY: `slot * row_len .. (slot + 1) * row_len` is in bounds
        // (slot < cap_rows) and disjoint from every row the view can
        // reach (see above).
        let row = unsafe {
            std::slice::from_raw_parts_mut(base.add(slot * self.row_len), self.row_len)
        };
        (view, row)
    }

    fn commit(&mut self) {
        self.len += 1;
    }

    /// Drop the backing allocation (used by [`SamplerEngine::run`] after
    /// materializing, so a one-shot run does not keep the flat trajectory
    /// resident alongside the nested copy). The next `reset` re-grows.
    fn release(&mut self) {
        self.data = Vec::new();
        self.len = 0;
    }

    /// Materialize nested rows (Record::Full stores only).
    pub fn to_nested(&self) -> Vec<Vec<f64>> {
        assert!(
            self.cap_rows >= self.len,
            "ring store dropped rows; use Record::Full"
        );
        (0..self.len).map(|i| self.row(i).to_vec()).collect()
    }
}

impl Default for NodeStore {
    fn default() -> Self {
        NodeStore::new()
    }
}

/// The workspace-pooled sampling driver. See the module docs.
pub struct SamplerEngine {
    cfg: EngineConfig,
    xs: NodeStore,
    ds: NodeStore,
    /// Solver scratch arena ([`Solver::scratch_spec`]); sized in
    /// `run_into`, never shrunk, carved into per-chunk [`StepScratch`]
    /// slices by `step_rows`.
    scratch: Vec<f64>,
}

impl SamplerEngine {
    pub fn new(cfg: EngineConfig) -> SamplerEngine {
        SamplerEngine {
            cfg,
            xs: NodeStore::new(),
            ds: NodeStore::new(),
            scratch: Vec::new(),
        }
    }

    /// Convenience constructor with auto thread sizing.
    pub fn with_record(record: Record) -> SamplerEngine {
        SamplerEngine::new(EngineConfig { record, threads: 0 })
    }

    pub fn config(&self) -> &EngineConfig {
        &self.cfg
    }

    /// Recorded states (valid after a `Record::Full` [`Self::run_into`];
    /// [`Self::run`] releases the workspace after materializing).
    pub fn xs(&self) -> &NodeStore {
        &self.xs
    }

    /// Recorded directions (valid after a `Record::Full`
    /// [`Self::run_into`]; [`Self::run`] releases the workspace after
    /// materializing).
    pub fn ds(&self) -> &NodeStore {
        &self.ds
    }

    /// Run the solver, writing the final samples into `x0_out` (shape
    /// `(n, dim)` flat). Returns the NFE spent. This is the
    /// allocation-free serving entry point: with `Record::None` and a
    /// warmed workspace, no step allocates.
    pub fn run_into(
        &mut self,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        x_t: &[f64],
        n: usize,
        sched: &Schedule,
        mut hook: Option<&mut dyn DirectionHook>,
        x0_out: &mut [f64],
    ) -> usize {
        let dim = model.dim();
        assert_eq!(x_t.len(), n * dim, "x_t must be (n, dim) flat");
        assert_eq!(x0_out.len(), n * dim, "x0_out must be (n, dim) flat");
        let row_len = n * dim;
        let n_steps = sched.n_steps();
        let (xs_cap, ds_cap) = match self.cfg.record {
            Record::Full => (n_steps + 1, n_steps.max(1)),
            Record::None => ((n_steps + 1).min(HIST_NODES), n_steps.max(1).min(HIST_NODES)),
        };
        self.xs.reset(row_len, xs_cap);
        self.ds.reset(row_len, ds_cap);
        // Solver scratch arena: enough for the whole batch's per-row
        // temporaries plus one flat block per possible chunk (chunk count
        // never exceeds the shard cap). Never shrunk, so repeated runs of
        // the same shape allocate nothing.
        let spec = solver.scratch_spec(dim, n);
        let max_parts = if self.cfg.threads == 0 {
            Pool::global().size()
        } else {
            self.cfg.threads
        };
        let scratch_need = spec.per_row * n + spec.flat * max_parts.max(1);
        if self.scratch.len() < scratch_need {
            self.scratch.resize(scratch_need, 0.0);
        }
        self.xs.push_row(x_t);
        let mut nfe = 0usize;
        for j in 0..n_steps {
            let t = sched.ts[j];
            let t_next = sched.ts[j + 1];
            let (xs_view, x_next) = self.xs.split_next();
            let (ds_view, d) = self.ds.split_next();
            let x_cur = xs_view.row(j);
            // Primary evaluation, straight into the direction row.
            model.eval_batch(x_cur, n, t, d);
            nfe += 1;
            let ctx = StepCtx {
                j,
                i_paper: n_steps - j,
                t,
                t_next,
                sched,
                xs: xs_view,
                ds: ds_view,
            };
            if let Some(h) = hook.as_deref_mut() {
                h.correct(&ctx, x_cur, n, d);
            }
            step_rows(
                self.cfg.threads,
                solver,
                model,
                &ctx,
                x_cur,
                d,
                n,
                dim,
                spec,
                &mut self.scratch,
                x_next,
            );
            nfe += solver.evals_per_step() - 1; // internal evals
            self.ds.commit();
            self.xs.commit();
        }
        x0_out.copy_from_slice(self.xs.row(n_steps));
        nfe
    }

    /// Run and materialize a legacy [`SolveRun`] (requires
    /// `Record::Full`). Bit-identical to [`super::run_solver_legacy`].
    ///
    /// Materialization copies the flat workspace into nested rows
    /// (transiently ~2x the trajectory footprint); the workspace is
    /// released afterwards so only the [`SolveRun`] remains resident.
    /// Callers that want the zero-copy flat trajectory should use
    /// [`Self::run_into`] and read [`Self::xs`]/[`Self::ds`] instead.
    pub fn run(
        &mut self,
        solver: &dyn Solver,
        model: &dyn EpsModel,
        x_t: &[f64],
        n: usize,
        sched: &Schedule,
        hook: Option<&mut dyn DirectionHook>,
    ) -> SolveRun {
        assert_eq!(
            self.cfg.record,
            Record::Full,
            "SolveRun materialization needs Record::Full; use run_into"
        );
        let mut x0 = vec![0.0; x_t.len()];
        let nfe = self.run_into(solver, model, x_t, n, sched, hook, &mut x0);
        let run = SolveRun {
            x0,
            xs: self.xs.to_nested(),
            ds: self.ds.to_nested(),
            nfe,
        };
        self.xs.release();
        self.ds.release();
        self.scratch = Vec::new();
        run
    }
}

/// Advance the batch, sharding rows across the pool when profitable.
/// Each shard receives column sub-views of the history and its own
/// disjoint [`StepScratch`] slice of the engine arena, so per-row
/// computation is exactly the sequential one. Multi-eval solvers shard
/// too: their internal model evaluations become per-chunk `eval_batch`
/// calls, which is bit-preserving because (and only when) the model is
/// row-independent — the `rows_independent` guard below.
///
/// `pub(crate)` so the PAS [`crate::pas::train::TrainSession`] can drive
/// its gamma-path solver steps (affine base, uncorrected next state)
/// through exactly the same sharded dispatch as the engine.
#[allow(clippy::too_many_arguments)]
pub(crate) fn step_rows(
    threads: usize,
    solver: &dyn Solver,
    model: &dyn EpsModel,
    ctx: &StepCtx<'_>,
    x: &[f64],
    d: &[f64],
    n: usize,
    dim: usize,
    spec: ScratchSpec,
    scratch: &mut [f64],
    out: &mut [f64],
) {
    let pool = Pool::global();
    let max_parts = if threads == 0 { pool.size() } else { threads };
    // The partition is computed up front (via the same `Pool::partition`
    // the dispatch uses) so each chunk's scratch slice can be located by
    // arithmetic: chunk c covers rows [c*chunk, (c+1)*chunk) and its
    // scratch starts at per_row * c * chunk + flat * c.
    //
    // Multi-eval solvers route their internal model evaluations through
    // per-chunk `eval_batch` calls, so their chunks are floored at the
    // model's preferred eval tile ([`EpsModel::preferred_tile`]) — a
    // sub-tile chunk would waste the blocked eval pipeline's panel
    // amortization. Purely a throughput knob: results are bit-identical
    // for every chunk layout (engine parity tests).
    let min_rows = if solver.evals_per_step() > 1 {
        model.preferred_tile().max(1)
    } else {
        1
    };
    let (chunk, n_chunks) = pool.partition(n, max_parts, min_rows);
    if max_parts <= 1
        || !solver.row_independent()
        || (solver.evals_per_step() != 1 && !model.rows_independent())
        || n < 2
        || n * dim < MIN_SHARD_ELEMS
        || n_chunks <= 1
    {
        let mut s = StepScratch::new(&mut scratch[..spec.len_for(n)]);
        solver.step(model, ctx, x, d, n, out, &mut s);
        return;
    }
    debug_assert!(spec.per_row * n + spec.flat * n_chunks <= scratch.len());
    let out_ptr = SendPtr::new(out.as_mut_ptr());
    let scratch_ptr = SendPtr::new(scratch.as_mut_ptr());
    pool.run(n_chunks, &|c| {
        let r0 = c * chunk;
        let r1 = ((c + 1) * chunk).min(n);
        let c0 = r0 * dim;
        let c1 = r1 * dim;
        let sub = StepCtx {
            j: ctx.j,
            i_paper: ctx.i_paper,
            t: ctx.t,
            t_next: ctx.t_next,
            sched: ctx.sched,
            xs: ctx.xs.cols(c0, c1 - c0),
            ds: ctx.ds.cols(c0, c1 - c0),
        };
        // SAFETY: pool chunk indices are distinct, so the row ranges —
        // and the scratch slices derived from them — are disjoint.
        let o = unsafe { std::slice::from_raw_parts_mut(out_ptr.get().add(c0), c1 - c0) };
        let s_off = spec.per_row * r0 + spec.flat * c;
        let s_len = spec.len_for(r1 - r0);
        let sbuf =
            unsafe { std::slice::from_raw_parts_mut(scratch_ptr.get().add(s_off), s_len) };
        let mut s = StepScratch::new(sbuf);
        solver.step(model, &sub, &x[c0..c1], &d[c0..c1], r1 - r0, o, &mut s);
    });
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::data::registry::get;
    use crate::schedule::default_schedule;
    use crate::score::analytic::AnalyticEps;
    use crate::score::counting::CountingEps;
    use crate::solvers::{registry, run_solver_legacy};
    use crate::traj::sample_prior;
    use crate::util::rng::Pcg64;

    #[test]
    fn full_record_matches_legacy_bitwise() {
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(8);
        let mut rng = Pcg64::seed(11);
        let n = 64;
        let x_t = sample_prior(&mut rng, n, 64, sched.t_max());
        let solver = registry::get("ddim").unwrap();
        let legacy = run_solver_legacy(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None);
        let mut eng = SamplerEngine::with_record(Record::Full);
        let run = eng.run(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None);
        assert_eq!(legacy.x0, run.x0);
        assert_eq!(legacy.xs, run.xs);
        assert_eq!(legacy.ds, run.ds);
        assert_eq!(legacy.nfe, run.nfe);
    }

    #[test]
    fn record_none_keeps_samples_and_nfe() {
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let counting = CountingEps::new(model.as_ref());
        let sched = default_schedule(10);
        let mut rng = Pcg64::seed(12);
        let n = 32;
        let x_t = sample_prior(&mut rng, n, 64, sched.t_max());
        let solver = registry::get("ipndm").unwrap();
        let legacy = run_solver_legacy(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None);
        let mut eng = SamplerEngine::with_record(Record::None);
        let mut x0 = vec![0.0; n * 64];
        let nfe = eng.run_into(solver.as_ref(), &counting, &x_t, n, &sched, None, &mut x0);
        assert_eq!(x0, legacy.x0);
        assert_eq!(nfe, 10);
        assert_eq!(counting.nfe(), 10);
    }

    #[test]
    fn workspace_reuse_across_runs_is_clean() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(6);
        let solver = registry::get("dpmpp3m").unwrap();
        let mut eng = SamplerEngine::with_record(Record::None);
        let mut rng = Pcg64::seed(13);
        for trial in 0..3 {
            let n = [8usize, 16, 8][trial];
            let x_t = sample_prior(&mut rng, n, 2, sched.t_max());
            let legacy =
                run_solver_legacy(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None);
            let mut x0 = vec![0.0; n * 2];
            eng.run_into(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None, &mut x0);
            assert_eq!(x0, legacy.x0, "trial {trial}");
        }
    }

    /// Multi-eval solvers (previously excluded from sharding) must be
    /// bit-identical to the legacy driver under sharded stepping, with
    /// sharding-invariant NFE accounting.
    #[test]
    fn multi_eval_solvers_shard_bitwise() {
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(6);
        let mut rng = Pcg64::seed(14);
        let n = 64;
        let x_t = sample_prior(&mut rng, n, 64, sched.t_max());
        for name in ["heun", "dpm2"] {
            let solver = registry::get(name).unwrap();
            let legacy =
                run_solver_legacy(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None);
            for threads in [2usize, 8] {
                let counting = CountingEps::new(model.as_ref());
                let mut eng = SamplerEngine::new(EngineConfig {
                    record: Record::None,
                    threads,
                });
                let mut x0 = vec![0.0; n * 64];
                let nfe =
                    eng.run_into(solver.as_ref(), &counting, &x_t, n, &sched, None, &mut x0);
                assert_eq!(legacy.x0, x0, "{name} sharded x0 (threads={threads})");
                assert_eq!(nfe, 12, "{name} logical NFE");
                assert_eq!(counting.nfe_rows(n), 12, "{name} row-accounted NFE");
            }
        }
    }

    /// A model that keys on absolute row indices reports
    /// `rows_independent() == false`; multi-eval solvers must then see
    /// only full-batch evaluations (no per-chunk internal calls).
    #[test]
    fn rows_dependent_model_keeps_multi_eval_unsharded() {
        struct FullBatchOnly<'a> {
            inner: &'a dyn crate::score::EpsModel,
            n_expect: usize,
        }
        impl crate::score::EpsModel for FullBatchOnly<'_> {
            fn dim(&self) -> usize {
                self.inner.dim()
            }
            fn rows_independent(&self) -> bool {
                false
            }
            fn eval_batch(&self, x: &[f64], n: usize, t: f64, out: &mut [f64]) {
                assert_eq!(n, self.n_expect, "rows-dependent model saw a chunk");
                self.inner.eval_batch(x, n, t, out);
            }
            fn name(&self) -> &str {
                "full-batch-only"
            }
        }
        let ds = get("gmm-hd64").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(4);
        let mut rng = Pcg64::seed(15);
        let n = 64; // n * dim = 4096: sharding would otherwise engage
        let x_t = sample_prior(&mut rng, n, 64, sched.t_max());
        let guard = FullBatchOnly {
            inner: model.as_ref(),
            n_expect: n,
        };
        let solver = registry::get("heun").unwrap();
        let mut eng = SamplerEngine::new(EngineConfig {
            record: Record::None,
            threads: 8,
        });
        let mut x0 = vec![0.0; n * 64];
        let nfe = eng.run_into(solver.as_ref(), &guard, &x_t, n, &sched, None, &mut x0);
        assert_eq!(nfe, 8);
    }

    #[test]
    #[should_panic(expected = "Record::Full")]
    fn run_requires_full_record() {
        let ds = get("gmm2d").unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let sched = default_schedule(3);
        let solver = registry::get("ddim").unwrap();
        let mut eng = SamplerEngine::with_record(Record::None);
        let _ = eng.run(solver.as_ref(), model.as_ref(), &[1.0, 1.0], 1, &sched, None);
    }
}
