//! Linear multistep solvers on the eps parameterization:
//!
//! * **iPNDM** (improved PNDM, Liu et al. 2022a as simplified by
//!   Zhang & Chen 2023): classical Adams–Bashforth coefficients with
//!   lower-order warm-up. Orders 1–4 (order 3 is the paper's default;
//!   order 1 coincides with DDIM).
//! * **DEIS-tAB3** (Zhang & Chen 2023): Adams–Bashforth in *t*-space with
//!   exact integration of the Lagrange interpolation polynomial over the
//!   step (the "time" AB variant), order 3.
//!
//! Both combine the current (possibly PAS-corrected) direction with the
//! recorded history `ctx.ds`, which already contains corrected directions
//! (Algorithm 1, line 17).

use super::{Solver, StepCtx};
use crate::score::EpsModel;

/// Classical AB coefficients, most-recent first.
const AB: [&[f64]; 4] = [
    &[1.0],
    &[1.5, -0.5],
    &[23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0],
    &[55.0 / 24.0, -59.0 / 24.0, 37.0 / 24.0, -9.0 / 24.0],
];

/// iPNDM with configurable order (1–4).
pub struct Ipndm {
    pub order: usize,
    name: String,
}

impl Ipndm {
    pub fn new(order: usize) -> Ipndm {
        assert!((1..=4).contains(&order), "iPNDM order must be 1..=4");
        Ipndm {
            order,
            name: format!("ipndm{order}"),
        }
    }

    fn effective_order(&self, ctx: &StepCtx<'_>) -> usize {
        self.order.min(ctx.ds.len() + 1)
    }
}

impl Solver for Ipndm {
    fn name(&self) -> &str {
        &self.name
    }

    fn gamma(&self, ctx: &StepCtx<'_>) -> Option<f64> {
        let ord = self.effective_order(ctx);
        Some(ctx.h() * AB[ord - 1][0])
    }

    fn step(
        &self,
        _model: &dyn EpsModel,
        ctx: &StepCtx<'_>,
        x: &[f64],
        d: &[f64],
        _n: usize,
        out: &mut [f64],
    ) {
        let ord = self.effective_order(ctx);
        let coefs = AB[ord - 1];
        let h = ctx.h();
        // out = x + h * (c0 d + c1 d_{-1} + ...)
        let c0 = coefs[0];
        for i in 0..x.len() {
            out[i] = x[i] + h * c0 * d[i];
        }
        for (k, &c) in coefs.iter().enumerate().skip(1) {
            let past = &ctx.ds[ctx.ds.len() - k];
            for i in 0..x.len() {
                out[i] += h * c * past[i];
            }
        }
    }
}

/// Exact integral over `[a, b]` of the Lagrange basis polynomials through
/// nodes `ts` (degree ts.len()-1). Returns one coefficient per node.
pub fn lagrange_integrals(ts: &[f64], a: f64, b: f64) -> Vec<f64> {
    let k = ts.len();
    let mut out = vec![0.0; k];
    for m in 0..k {
        // Build monomial coefficients of L_m(s) = prod_{l != m} (s - t_l)/(t_m - t_l).
        let mut poly = vec![1.0f64]; // coefficients, low -> high degree
        let mut denom = 1.0;
        for (l, &tl) in ts.iter().enumerate() {
            if l == m {
                continue;
            }
            denom *= ts[m] - tl;
            // poly *= (s - tl)
            let mut next = vec![0.0; poly.len() + 1];
            for (p, &c) in poly.iter().enumerate() {
                next[p] -= c * tl;
                next[p + 1] += c;
            }
            poly = next;
        }
        // Integrate: ∫ s^p ds = (b^{p+1} − a^{p+1})/(p+1).
        let mut integral = 0.0;
        for (p, &c) in poly.iter().enumerate() {
            let q = (p + 1) as f64;
            integral += c * (b.powi(p as i32 + 1) - a.powi(p as i32 + 1)) / q;
        }
        out[m] = integral / denom;
    }
    out
}

/// DEIS "time-AB" solver of a given order (paper baseline: order 3).
pub struct DeisTab {
    pub order: usize,
    name: String,
}

impl DeisTab {
    pub fn new(order: usize) -> DeisTab {
        assert!((1..=4).contains(&order));
        DeisTab {
            order,
            name: format!("deis-tab{order}"),
        }
    }

    /// Nodes used at this step, most recent first: t_j, t_{j-1}, ...
    fn nodes(&self, ctx: &StepCtx<'_>) -> Vec<f64> {
        let avail = ctx.ds.len();
        let k = self.order.min(avail + 1);
        (0..k).map(|m| ctx.sched.ts[ctx.j - m]).collect()
    }
}

impl Solver for DeisTab {
    fn name(&self) -> &str {
        &self.name
    }

    fn gamma(&self, ctx: &StepCtx<'_>) -> Option<f64> {
        let nodes = self.nodes(ctx);
        let c = lagrange_integrals(&nodes, ctx.t, ctx.t_next);
        Some(c[0])
    }

    fn step(
        &self,
        _model: &dyn EpsModel,
        ctx: &StepCtx<'_>,
        x: &[f64],
        d: &[f64],
        _n: usize,
        out: &mut [f64],
    ) {
        let nodes = self.nodes(ctx);
        let coefs = lagrange_integrals(&nodes, ctx.t, ctx.t_next);
        for i in 0..x.len() {
            out[i] = x[i] + coefs[0] * d[i];
        }
        for (m, &c) in coefs.iter().enumerate().skip(1) {
            let past = &ctx.ds[ctx.ds.len() - m];
            for i in 0..x.len() {
                out[i] += c * past[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::score::EpsModel;
    use crate::solvers::{euler::Euler, run_solver};

    struct LinearEps;
    impl EpsModel for LinearEps {
        fn dim(&self) -> usize {
            1
        }
        fn eval_batch(&self, x: &[f64], _n: usize, t: f64, out: &mut [f64]) {
            for i in 0..x.len() {
                out[i] = x[i] / t;
            }
        }
        fn name(&self) -> &str {
            "linear"
        }
    }

    #[test]
    fn ipndm1_equals_ddim() {
        let sched = Schedule::log_snr(6, 1.0, 10.0);
        let a = run_solver(&Ipndm::new(1), &LinearEps, &[10.0], 1, &sched, None);
        let b = run_solver(&Euler, &LinearEps, &[10.0], 1, &sched, None);
        assert_eq!(a.x0, b.x0);
    }

    /// Curved test ODE (unit-Gaussian score): Euler is not exact, and the
    /// exact solution is x(t') = x(t) sqrt((1+t'²)/(1+t²)).
    struct CurvedEps;
    impl EpsModel for CurvedEps {
        fn dim(&self) -> usize {
            1
        }
        fn eval_batch(&self, x: &[f64], _n: usize, t: f64, out: &mut [f64]) {
            for i in 0..x.len() {
                out[i] = t * x[i] / (1.0 + t * t);
            }
        }
        fn name(&self) -> &str {
            "curved"
        }
    }

    #[test]
    fn higher_order_is_more_accurate() {
        let sched = Schedule::log_snr(12, 1.0, 10.0);
        let exact = 10.0 * (2.0f64 / 101.0).sqrt();
        let errs: Vec<f64> = (1..=4)
            .map(|k| {
                (run_solver(&Ipndm::new(k), &CurvedEps, &[10.0], 1, &sched, None).x0[0] - exact)
                    .abs()
            })
            .collect();
        assert!(errs[1] < errs[0], "{errs:?}");
        assert!(errs[2] < errs[1], "{errs:?}");
    }

    #[test]
    fn lagrange_integrals_constant_rule() {
        // Interpolating a constant: coefficients must sum to b - a.
        let c = lagrange_integrals(&[3.0, 2.0, 1.0], 3.0, 2.5);
        let s: f64 = c.iter().sum();
        assert!((s - (-0.5)).abs() < 1e-12, "{c:?}");
    }

    #[test]
    fn lagrange_integrals_exact_for_polynomials() {
        // f(s) = s^2 through 3 nodes must integrate exactly.
        let nodes = [4.0, 3.0, 1.5];
        let c = lagrange_integrals(&nodes, 4.0, 2.0);
        let approx: f64 = c.iter().zip(nodes.iter()).map(|(ci, t)| ci * t * t).sum();
        let exact = (2.0f64.powi(3) - 4.0f64.powi(3)) / 3.0;
        assert!((approx - exact).abs() < 1e-10, "{approx} vs {exact}");
    }

    #[test]
    fn deis_beats_euler_on_curved_ode() {
        let sched = Schedule::polynomial(12, 0.5, 10.0, 7.0);
        let exact = 10.0 * ((1.0_f64 + 0.25) / 101.0).sqrt();
        let e_deis =
            (run_solver(&DeisTab::new(3), &CurvedEps, &[10.0], 1, &sched, None).x0[0] - exact)
                .abs();
        let e_euler =
            (run_solver(&Euler, &CurvedEps, &[10.0], 1, &sched, None).x0[0] - exact).abs();
        // The t-space AB with exact quadrature weights for the non-uniform
        // grid should comfortably beat first-order Euler.
        assert!(e_deis < e_euler * 0.5, "deis {e_deis} vs euler {e_euler}");
    }

    #[test]
    fn gamma_matches_step_sensitivity() {
        // Finite-difference check: perturb the current direction and
        // compare against gamma.
        let sched = Schedule::polynomial(5, 0.5, 10.0, 7.0);
        for solver in [&Ipndm::new(3) as &dyn Solver, &DeisTab::new(3)] {
            let ds = vec![vec![0.3], vec![-0.2]];
            let xs = vec![vec![1.0], vec![0.9], vec![0.8]];
            let ctx = StepCtx {
                j: 2,
                i_paper: 3,
                t: sched.ts[2],
                t_next: sched.ts[3],
                sched: &sched,
                xs: crate::solvers::NodeView::nested(&xs),
                ds: crate::solvers::NodeView::nested(&ds),
            };
            let gamma = solver.gamma(&ctx).unwrap();
            let mut out0 = vec![0.0];
            let mut out1 = vec![0.0];
            solver.step(&LinearEps, &ctx, &[0.8], &[0.5], 1, &mut out0);
            solver.step(&LinearEps, &ctx, &[0.8], &[0.5 + 1e-6], 1, &mut out1);
            let fd = (out1[0] - out0[0]) / 1e-6;
            assert!(
                (fd - gamma).abs() < 1e-6 * (1.0 + gamma.abs()),
                "{}: fd {fd} vs gamma {gamma}",
                solver.name()
            );
        }
    }
}
