//! Linear multistep solvers on the eps parameterization:
//!
//! * **iPNDM** (improved PNDM, Liu et al. 2022a as simplified by
//!   Zhang & Chen 2023): classical Adams–Bashforth coefficients with
//!   lower-order warm-up. Orders 1–4 (order 3 is the paper's default;
//!   order 1 coincides with DDIM).
//! * **DEIS-tAB3** (Zhang & Chen 2023): Adams–Bashforth in *t*-space with
//!   exact integration of the Lagrange interpolation polynomial over the
//!   step (the "time" AB variant), order 3.
//!
//! Both combine the current (possibly PAS-corrected) direction with the
//! recorded history `ctx.ds`, which already contains corrected directions
//! (Algorithm 1, line 17).

use super::{Solver, StepCtx, StepScratch};
use crate::score::EpsModel;

/// Classical AB coefficients, most-recent first.
const AB: [&[f64]; 4] = [
    &[1.0],
    &[1.5, -0.5],
    &[23.0 / 12.0, -16.0 / 12.0, 5.0 / 12.0],
    &[55.0 / 24.0, -59.0 / 24.0, 37.0 / 24.0, -9.0 / 24.0],
];

/// iPNDM with configurable order (1–4).
pub struct Ipndm {
    /// Private so the `new` invariant (1..=4, the AB table depth) cannot
    /// be bypassed after construction.
    order: usize,
    name: String,
}

impl Ipndm {
    pub fn new(order: usize) -> Ipndm {
        assert!((1..=4).contains(&order), "iPNDM order must be 1..=4");
        Ipndm {
            order,
            name: format!("ipndm{order}"),
        }
    }

    fn effective_order(&self, ctx: &StepCtx<'_>) -> usize {
        self.order.min(ctx.ds.len() + 1)
    }
}

impl Solver for Ipndm {
    fn name(&self) -> &str {
        &self.name
    }

    fn gamma(&self, ctx: &StepCtx<'_>) -> Option<f64> {
        let ord = self.effective_order(ctx);
        Some(ctx.h() * AB[ord - 1][0])
    }

    fn hist_depth(&self) -> usize {
        // Deepest read: ds[len - k] for k ≤ order - 1, i.e. order - 1
        // steps back from the current node.
        self.order - 1
    }

    fn step(
        &self,
        _model: &dyn EpsModel,
        ctx: &StepCtx<'_>,
        x: &[f64],
        d: &[f64],
        _n: usize,
        out: &mut [f64],
        _scratch: &mut StepScratch<'_>,
    ) {
        let ord = self.effective_order(ctx);
        let coefs = AB[ord - 1];
        let h = ctx.h();
        // out = x + h * (c0 d + c1 d_{-1} + ...)
        let c0 = coefs[0];
        for i in 0..x.len() {
            out[i] = x[i] + h * c0 * d[i];
        }
        for (k, &c) in coefs.iter().enumerate().skip(1) {
            let past = &ctx.ds[ctx.ds.len() - k];
            for i in 0..x.len() {
                out[i] += h * c * past[i];
            }
        }
    }
}

/// Exact integral over `[a, b]` of the Lagrange basis polynomials through
/// nodes `ts` (degree ts.len()-1). Returns one coefficient per node.
/// Heap-allocating general-`k` version; the solver hot path uses
/// [`lagrange_integrals_into`], which is bit-identical for `k <=`
/// [`LAGRANGE_STACK_K`] (a test pins that).
pub fn lagrange_integrals(ts: &[f64], a: f64, b: f64) -> Vec<f64> {
    let k = ts.len();
    let mut out = vec![0.0; k];
    for m in 0..k {
        // Build monomial coefficients of L_m(s) = prod_{l != m} (s - t_l)/(t_m - t_l).
        let mut poly = vec![1.0f64]; // coefficients, low -> high degree
        let mut denom = 1.0;
        for (l, &tl) in ts.iter().enumerate() {
            if l == m {
                continue;
            }
            denom *= ts[m] - tl;
            // poly *= (s - tl)
            let mut next = vec![0.0; poly.len() + 1];
            for (p, &c) in poly.iter().enumerate() {
                next[p] -= c * tl;
                next[p + 1] += c;
            }
            poly = next;
        }
        // Integrate: ∫ s^p ds = (b^{p+1} − a^{p+1})/(p+1).
        let mut integral = 0.0;
        for (p, &c) in poly.iter().enumerate() {
            let q = (p + 1) as f64;
            integral += c * (b.powi(p as i32 + 1) - a.powi(p as i32 + 1)) / q;
        }
        out[m] = integral / denom;
    }
    out
}

/// Max node count [`lagrange_integrals_into`] supports with stack-only
/// temporaries (registered AB solvers use order <= 4).
pub const LAGRANGE_STACK_K: usize = 6;

/// Allocation-free [`lagrange_integrals`]: writes the `ts.len()`
/// coefficients into `out[..ts.len()]` using fixed-size stack buffers.
/// Per-coefficient arithmetic mirrors the Vec version operation-for-
/// operation, so the two are bit-identical (asserted by a unit test) —
/// this is what lets `DeisTab::step` run without heap allocation while
/// `run_solver_legacy` stays the bitwise oracle.
pub fn lagrange_integrals_into(ts: &[f64], a: f64, b: f64, out: &mut [f64]) {
    let k = ts.len();
    assert!(k <= LAGRANGE_STACK_K, "k={k} exceeds stack capacity");
    assert!(out.len() >= k, "out too short for {k} coefficients");
    for m in 0..k {
        // poly *= (s - tl), updated in place high -> low degree. Entry q
        // of the Vec version's `next` receives `poly[q-1]` (the += at
        // p = q-1) before `- poly[q]*tl` (the -= at p = q), so the
        // in-place update below reproduces the exact same two operations
        // in the same order.
        let mut poly = [0.0f64; LAGRANGE_STACK_K + 1];
        poly[0] = 1.0;
        let mut deg = 0usize;
        let mut denom = 1.0;
        for (l, &tl) in ts.iter().enumerate() {
            if l == m {
                continue;
            }
            denom *= ts[m] - tl;
            #[allow(clippy::identity_op)]
            {
                poly[deg + 1] = 0.0 + poly[deg];
                for q in (1..=deg).rev() {
                    poly[q] = (0.0 + poly[q - 1]) - poly[q] * tl;
                }
                poly[0] = 0.0 - poly[0] * tl;
            }
            deg += 1;
        }
        let mut integral = 0.0;
        for (p, &c) in poly.iter().enumerate().take(deg + 1) {
            let q = (p + 1) as f64;
            integral += c * (b.powi(p as i32 + 1) - a.powi(p as i32 + 1)) / q;
        }
        out[m] = integral / denom;
    }
}

/// DEIS "time-AB" solver of a given order (paper baseline: order 3).
pub struct DeisTab {
    /// Private so the `new` invariant (1..=4, the size of `step`'s stack
    /// node/coefficient buffers) cannot be bypassed after construction.
    order: usize,
    name: String,
}

impl DeisTab {
    pub fn new(order: usize) -> DeisTab {
        assert!((1..=4).contains(&order));
        DeisTab {
            order,
            name: format!("deis-tab{order}"),
        }
    }

    /// Nodes used at this step, most recent first (t_j, t_{j-1}, ...),
    /// written into `out`; returns the count (≤ order ≤ 4).
    fn nodes_into(&self, ctx: &StepCtx<'_>, out: &mut [f64; 4]) -> usize {
        let avail = ctx.ds.len();
        let k = self.order.min(avail + 1);
        for (m, o) in out.iter_mut().enumerate().take(k) {
            *o = ctx.sched.ts[ctx.j - m];
        }
        k
    }
}

impl Solver for DeisTab {
    fn name(&self) -> &str {
        &self.name
    }

    fn gamma(&self, ctx: &StepCtx<'_>) -> Option<f64> {
        let mut nodes = [0.0f64; 4];
        let k = self.nodes_into(ctx, &mut nodes);
        let mut coefs = [0.0f64; 4];
        lagrange_integrals_into(&nodes[..k], ctx.t, ctx.t_next, &mut coefs[..k]);
        Some(coefs[0])
    }

    fn hist_depth(&self) -> usize {
        // Deepest read: ds[len - m] for m ≤ order - 1.
        self.order - 1
    }

    // Quadrature temporaries are stack arrays (order <= 4), so no arena
    // scratch is needed: the default ScratchSpec::NONE applies.
    fn step(
        &self,
        _model: &dyn EpsModel,
        ctx: &StepCtx<'_>,
        x: &[f64],
        d: &[f64],
        _n: usize,
        out: &mut [f64],
        _scratch: &mut StepScratch<'_>,
    ) {
        let mut nodes = [0.0f64; 4];
        let k = self.nodes_into(ctx, &mut nodes);
        let mut coefs = [0.0f64; 4];
        lagrange_integrals_into(&nodes[..k], ctx.t, ctx.t_next, &mut coefs[..k]);
        for i in 0..x.len() {
            out[i] = x[i] + coefs[0] * d[i];
        }
        for (m, &c) in coefs.iter().enumerate().take(k).skip(1) {
            let past = &ctx.ds[ctx.ds.len() - m];
            for i in 0..x.len() {
                out[i] += c * past[i];
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::score::EpsModel;
    use crate::solvers::{euler::Euler, run_solver};

    struct LinearEps;
    impl EpsModel for LinearEps {
        fn dim(&self) -> usize {
            1
        }
        fn eval_batch(&self, x: &[f64], _n: usize, t: f64, out: &mut [f64]) {
            for i in 0..x.len() {
                out[i] = x[i] / t;
            }
        }
        fn name(&self) -> &str {
            "linear"
        }
    }

    #[test]
    fn ipndm1_equals_ddim() {
        let sched = Schedule::log_snr(6, 1.0, 10.0);
        let a = run_solver(&Ipndm::new(1), &LinearEps, &[10.0], 1, &sched, None);
        let b = run_solver(&Euler, &LinearEps, &[10.0], 1, &sched, None);
        assert_eq!(a.x0, b.x0);
    }

    /// Curved test ODE (unit-Gaussian score): Euler is not exact, and the
    /// exact solution is x(t') = x(t) sqrt((1+t'²)/(1+t²)).
    struct CurvedEps;
    impl EpsModel for CurvedEps {
        fn dim(&self) -> usize {
            1
        }
        fn eval_batch(&self, x: &[f64], _n: usize, t: f64, out: &mut [f64]) {
            for i in 0..x.len() {
                out[i] = t * x[i] / (1.0 + t * t);
            }
        }
        fn name(&self) -> &str {
            "curved"
        }
    }

    #[test]
    fn higher_order_is_more_accurate() {
        let sched = Schedule::log_snr(12, 1.0, 10.0);
        let exact = 10.0 * (2.0f64 / 101.0).sqrt();
        let errs: Vec<f64> = (1..=4)
            .map(|k| {
                (run_solver(&Ipndm::new(k), &CurvedEps, &[10.0], 1, &sched, None).x0[0] - exact)
                    .abs()
            })
            .collect();
        assert!(errs[1] < errs[0], "{errs:?}");
        assert!(errs[2] < errs[1], "{errs:?}");
    }

    #[test]
    fn lagrange_integrals_constant_rule() {
        // Interpolating a constant: coefficients must sum to b - a.
        let c = lagrange_integrals(&[3.0, 2.0, 1.0], 3.0, 2.5);
        let s: f64 = c.iter().sum();
        assert!((s - (-0.5)).abs() < 1e-12, "{c:?}");
    }

    #[test]
    fn lagrange_integrals_exact_for_polynomials() {
        // f(s) = s^2 through 3 nodes must integrate exactly.
        let nodes = [4.0, 3.0, 1.5];
        let c = lagrange_integrals(&nodes, 4.0, 2.0);
        let approx: f64 = c.iter().zip(nodes.iter()).map(|(ci, t)| ci * t * t).sum();
        let exact = (2.0f64.powi(3) - 4.0f64.powi(3)) / 3.0;
        assert!((approx - exact).abs() < 1e-10, "{approx} vs {exact}");
    }

    /// The stack-buffer quadrature path used by `DeisTab::step` must be
    /// bit-identical to the heap version `run_solver_legacy`-era code
    /// used — this is what keeps the legacy driver a valid oracle.
    #[test]
    fn lagrange_into_matches_vec_bitwise() {
        let mut rng = crate::util::rng::Pcg64::seed(77);
        for _trial in 0..200 {
            let k = 1 + rng.below(4);
            // Strictly decreasing positive nodes, EDM-style.
            let mut nodes = vec![0.0f64; k];
            let mut t = 5.0 + rng.uniform() * 5.0;
            for node in nodes.iter_mut() {
                *node = t;
                t *= 0.3 + rng.uniform() * 0.6;
            }
            let a = nodes[0];
            let b = a * (0.3 + rng.uniform() * 0.6);
            let want = lagrange_integrals(&nodes, a, b);
            let mut got = [0.0f64; 4];
            lagrange_integrals_into(&nodes, a, b, &mut got[..k]);
            for m in 0..k {
                assert_eq!(
                    want[m].to_bits(),
                    got[m].to_bits(),
                    "k={k} m={m}: {} vs {}",
                    want[m],
                    got[m]
                );
            }
        }
    }

    #[test]
    fn deis_beats_euler_on_curved_ode() {
        let sched = Schedule::polynomial(12, 0.5, 10.0, 7.0);
        let exact = 10.0 * ((1.0_f64 + 0.25) / 101.0).sqrt();
        let e_deis =
            (run_solver(&DeisTab::new(3), &CurvedEps, &[10.0], 1, &sched, None).x0[0] - exact)
                .abs();
        let e_euler =
            (run_solver(&Euler, &CurvedEps, &[10.0], 1, &sched, None).x0[0] - exact).abs();
        // The t-space AB with exact quadrature weights for the non-uniform
        // grid should comfortably beat first-order Euler.
        assert!(e_deis < e_euler * 0.5, "deis {e_deis} vs euler {e_euler}");
    }

    #[test]
    fn gamma_matches_step_sensitivity() {
        // Finite-difference check: perturb the current direction and
        // compare against gamma.
        let sched = Schedule::polynomial(5, 0.5, 10.0, 7.0);
        for solver in [&Ipndm::new(3) as &dyn Solver, &DeisTab::new(3)] {
            let ds = vec![vec![0.3], vec![-0.2]];
            let xs = vec![vec![1.0], vec![0.9], vec![0.8]];
            let ctx = StepCtx {
                j: 2,
                i_paper: 3,
                t: sched.ts[2],
                t_next: sched.ts[3],
                sched: &sched,
                xs: crate::solvers::NodeView::nested(&xs),
                ds: crate::solvers::NodeView::nested(&ds),
            };
            let gamma = solver.gamma(&ctx).unwrap();
            let mut out0 = vec![0.0];
            let mut out1 = vec![0.0];
            let mut buf = vec![0.0; solver.scratch_spec(1, 1).len_for(1)];
            let mut s0 = crate::solvers::StepScratch::new(&mut buf);
            solver.step(&LinearEps, &ctx, &[0.8], &[0.5], 1, &mut out0, &mut s0);
            let mut s1 = crate::solvers::StepScratch::new(&mut buf);
            solver.step(&LinearEps, &ctx, &[0.8], &[0.5 + 1e-6], 1, &mut out1, &mut s1);
            let fd = (out1[0] - out0[0]) / 1e-6;
            assert!(
                (fd - gamma).abs() < 1e-6 * (1.0 + gamma.abs()),
                "{}: fd {fd} vs gamma {gamma}",
                solver.name()
            );
        }
    }
}
