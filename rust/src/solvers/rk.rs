//! Single-step Runge–Kutta style solvers: Heun's 2nd (EDM) and
//! DPM-Solver-2. Both spend 2 NFE per step, hence the "\\" cells at odd
//! NFE in the paper's tables — `steps_for_nfe` returns `None` there.

use super::{ScratchSpec, Solver, StepCtx, StepScratch};
use crate::score::EpsModel;

/// Heun's 2nd order solver (Karras et al. 2022): Euler predictor followed
/// by a trapezoidal correction. Used in this repo mainly as the *teacher*
/// for ground-truth trajectories (paper §4.1 uses Heun with 100 NFE).
pub struct Heun;

impl Solver for Heun {
    fn name(&self) -> &str {
        "heun"
    }

    fn evals_per_step(&self) -> usize {
        2
    }

    fn gamma(&self, _ctx: &StepCtx<'_>) -> Option<f64> {
        None // second eval depends on d nonlinearly through x_pred
    }

    fn hist_depth(&self) -> usize {
        0 // both evals derive from the current node
    }

    fn scratch_spec(&self, dim: usize, _n: usize) -> ScratchSpec {
        // d2: the corrector's direction at the predicted state.
        ScratchSpec {
            per_row: dim,
            flat: 0,
        }
    }

    fn step(
        &self,
        model: &dyn EpsModel,
        ctx: &StepCtx<'_>,
        x: &[f64],
        d: &[f64],
        n: usize,
        out: &mut [f64],
        scratch: &mut StepScratch<'_>,
    ) {
        let h = ctx.h();
        // Predictor.
        for i in 0..x.len() {
            out[i] = x[i] + h * d[i];
        }
        // Corrector.
        let d2 = scratch.take(x.len());
        model.eval_batch(out, n, ctx.t_next, d2);
        for i in 0..x.len() {
            out[i] = x[i] + 0.5 * h * (d[i] + d2[i]);
        }
    }
}

/// DPM-Solver-2 (Lu et al. 2022a) with midpoint ratio r = 1/2. In the EDM
/// eps form with `lambda = -ln t`, the lambda-midpoint is the geometric
/// mean `t_mid = sqrt(t t')`:
///
/// ```text
/// x_mid = x + (t_mid − t) eps(x, t)
/// x'    = x + (t' − t)    eps(x_mid, t_mid)
/// ```
pub struct Dpm2;

impl Solver for Dpm2 {
    fn name(&self) -> &str {
        "dpm2"
    }

    fn evals_per_step(&self) -> usize {
        2
    }

    fn gamma(&self, _ctx: &StepCtx<'_>) -> Option<f64> {
        None
    }

    fn hist_depth(&self) -> usize {
        0 // midpoint eval derives from the current node
    }

    fn scratch_spec(&self, dim: usize, _n: usize) -> ScratchSpec {
        // x_mid + d_mid.
        ScratchSpec {
            per_row: 2 * dim,
            flat: 0,
        }
    }

    fn step(
        &self,
        model: &dyn EpsModel,
        ctx: &StepCtx<'_>,
        x: &[f64],
        d: &[f64],
        n: usize,
        out: &mut [f64],
        scratch: &mut StepScratch<'_>,
    ) {
        let t_mid = (ctx.t * ctx.t_next).sqrt();
        let x_mid = scratch.take(x.len());
        for i in 0..x.len() {
            x_mid[i] = x[i] + (t_mid - ctx.t) * d[i];
        }
        let d_mid = scratch.take(x.len());
        model.eval_batch(x_mid, n, t_mid, d_mid);
        let h = ctx.h();
        for i in 0..x.len() {
            out[i] = x[i] + h * d_mid[i];
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::schedule::Schedule;
    use crate::score::EpsModel;
    use crate::solvers::{euler::Euler, run_solver, Solver};

    /// Curved test ODE: eps(x,t) = t x / (1 + t²) — the unit-Gaussian
    /// score, with exact solution x(t') = x(t) sqrt((1+t'²)/(1+t²)).
    /// (Unlike eps = x/t, Euler is NOT exact on this one.)
    struct CurvedEps;
    impl EpsModel for CurvedEps {
        fn dim(&self) -> usize {
            1
        }
        fn eval_batch(&self, x: &[f64], _n: usize, t: f64, out: &mut [f64]) {
            for i in 0..x.len() {
                out[i] = t * x[i] / (1.0 + t * t);
            }
        }
        fn name(&self) -> &str {
            "curved"
        }
    }

    fn exact(x: f64, t_from: f64, t_to: f64) -> f64 {
        x * ((1.0 + t_to * t_to) / (1.0 + t_from * t_from)).sqrt()
    }

    #[test]
    fn nfe_accounting() {
        assert_eq!(Heun.steps_for_nfe(10), Some(5));
        assert_eq!(Heun.steps_for_nfe(5), None);
        assert_eq!(Dpm2.steps_for_nfe(8), Some(4));
        assert_eq!(Dpm2.steps_for_nfe(7), None);
    }

    #[test]
    fn second_order_beats_euler_at_equal_steps() {
        let sched = Schedule::log_snr(10, 1.0, 10.0);
        let want = exact(10.0, 10.0, 1.0);
        let e = run_solver(&Euler, &CurvedEps, &[10.0], 1, &sched, None);
        let h = run_solver(&Heun, &CurvedEps, &[10.0], 1, &sched, None);
        let d2 = run_solver(&Dpm2, &CurvedEps, &[10.0], 1, &sched, None);
        let err = |v: f64| (v - want).abs();
        assert!(err(h.x0[0]) < err(e.x0[0]) * 0.5, "heun {} euler {}", h.x0[0], e.x0[0]);
        assert!(err(d2.x0[0]) < err(e.x0[0]) * 0.5, "dpm2 {} euler {}", d2.x0[0], e.x0[0]);
    }

    #[test]
    fn nfe_spent_matches_declared() {
        let sched = Schedule::log_snr(4, 1.0, 10.0);
        let run = run_solver(&Heun, &CurvedEps, &[10.0], 1, &sched, None);
        assert_eq!(run.nfe, 8);
    }

    /// Heun converges at order 2: quartering the step size should cut the
    /// error by ~16x (we assert at least 8x to be robust).
    #[test]
    fn heun_convergence_order() {
        let want = exact(10.0, 10.0, 1.0);
        let err = |n: usize| {
            let sched = Schedule::log_snr(n, 1.0, 10.0);
            (run_solver(&Heun, &CurvedEps, &[10.0], 1, &sched, None).x0[0] - want).abs()
        };
        let e1 = err(8);
        let e2 = err(32);
        assert!(e2 < e1 / 8.0, "e(8)={e1} e(32)={e2}");
    }
}
