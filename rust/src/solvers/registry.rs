//! Name → solver registry (CLI, configs, experiment harness).

use super::dpmpp::DpmPp;
use super::euler::Euler;
use super::multistep::{DeisTab, Ipndm};
use super::rk::{Dpm2, Heun};
use super::unipc::UniPc;
use super::Solver;

/// All registered solver names.
pub const ALL: &[&str] = &[
    "ddim",
    "heun",
    "dpm2",
    "dpmpp2m",
    "dpmpp3m",
    "deis-tab3",
    "unipc3m",
    "ipndm1",
    "ipndm2",
    "ipndm3",
    "ipndm4",
    "ipndm", // alias for the paper's default order 3
];

/// Look up a solver by name.
pub fn get(name: &str) -> Option<Box<dyn Solver>> {
    Some(match name {
        "ddim" | "euler" => Box::new(Euler),
        "heun" => Box::new(Heun),
        "dpm2" => Box::new(Dpm2),
        "dpmpp2m" => Box::new(DpmPp::new(2)),
        "dpmpp3m" => Box::new(DpmPp::new(3)),
        "deis-tab1" => Box::new(DeisTab::new(1)),
        "deis-tab2" => Box::new(DeisTab::new(2)),
        "deis-tab3" => Box::new(DeisTab::new(3)),
        "unipc1m" => Box::new(UniPc::new(1)),
        "unipc2m" => Box::new(UniPc::new(2)),
        "unipc3m" => Box::new(UniPc::new(3)),
        "ipndm1" => Box::new(Ipndm::new(1)),
        "ipndm2" => Box::new(Ipndm::new(2)),
        "ipndm3" | "ipndm" => Box::new(Ipndm::new(3)),
        "ipndm4" => Box::new(Ipndm::new(4)),
        _ => return None,
    })
}

/// Solvers PAS can correct (those exposing a linear `gamma`): the paper
/// applies PAS to DDIM and iPNDM; DEIS and DPM++ also qualify here.
pub fn supports_pas(name: &str) -> bool {
    matches!(
        name,
        "ddim" | "euler" | "ipndm" | "ipndm1" | "ipndm2" | "ipndm3" | "ipndm4"
            | "deis-tab1" | "deis-tab2" | "deis-tab3" | "dpmpp2m" | "dpmpp3m"
    )
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn all_resolve() {
        for name in ALL {
            let s = get(name).unwrap_or_else(|| panic!("missing {name}"));
            assert!(!s.name().is_empty());
        }
        assert!(get("nope").is_none());
    }

    #[test]
    fn alias_matches_order3() {
        assert_eq!(get("ipndm").unwrap().name(), "ipndm3");
    }

    #[test]
    fn declared_hist_depths_are_pinned() {
        use crate::solvers::engine::HIST_NODES;
        // Every registered depth must fit the engine's retention bound.
        for name in ALL {
            let s = get(name).unwrap();
            assert!(
                s.hist_depth() <= HIST_NODES - 2,
                "{name} declares a deeper lookback than the engine retains"
            );
        }
        // Pin the known values so deepening a solver's history reads
        // forces its declaration (and this table) to be updated in step.
        for (name, depth) in [
            ("ddim", 0),
            ("heun", 0),
            ("dpm2", 0),
            ("ipndm1", 0),
            ("ipndm2", 1),
            ("ipndm3", 2),
            ("ipndm", 2),
            ("ipndm4", 3),
            ("deis-tab1", 0),
            ("deis-tab2", 1),
            ("deis-tab3", 2),
            ("dpmpp2m", 1),
            ("dpmpp3m", 2),
            ("unipc1m", 1),
            ("unipc2m", 2),
            ("unipc3m", 3),
        ] {
            assert_eq!(get(name).unwrap().hist_depth(), depth, "{name}");
        }
    }

    #[test]
    fn pas_support_flags() {
        assert!(supports_pas("ddim"));
        assert!(supports_pas("ipndm"));
        assert!(!supports_pas("heun"));
        assert!(!supports_pas("unipc3m"));
    }
}
