//! Bench: timed end-to-end regeneration of the paper's headline cells
//! (quick sizes) — proves every table's pipeline runs and tracks its cost.

#[path = "harness.rs"]
mod harness;

use pas::experiments::common::{eval_cell, Bench, Cell};
use pas::experiments::ExpOpts;

fn main() {
    println!("== e2e_tables: headline cells at quick sizes ==");
    let opts = ExpOpts::quick();
    let bench = Bench::new("gmm-hd64", 0.0, &opts);
    for (label, cell) in [
        ("table2: ddim@10", Cell::plain("ddim", 10)),
        ("table2: ddim+PAS@10 (train+sample)", Cell::pas("ddim", 10)),
        ("table2: ipndm@10", Cell::plain("ipndm", 10)),
        ("table2: unipc3m@10", Cell::plain("unipc3m", 10)),
        (
            "table2: ddim+TP+PAS@10",
            Cell {
                tp: true,
                ..Cell::pas("ddim", 10)
            },
        ),
    ] {
        harness::bench(label, 0, 2, 0.2, || {
            harness::black_box(eval_cell(&bench, &cell, &opts));
        });
    }
    // One full quick experiment as the macro benchmark.
    harness::bench("fig3 (full runner, quick)", 0, 1, 0.0, || {
        harness::black_box(pas::experiments::run("fig3", &opts).unwrap());
    });
}
