//! Bench: the §3.5 cost claim — one PCA-based correction must be
//! negligible next to one model evaluation (paper: 0.06 s vs 30.2 s on
//! Stable Diffusion = 0.2 %). We measure the PCA basis + reconstruction
//! against (a) the analytic model and (b) the PJRT denoiser when
//! artifacts are present.

#[path = "harness.rs"]
mod harness;

use pas::pas::pca::{pca_basis, TrajBuffer};
use pas::score::analytic::AnalyticEps;
use pas::score::EpsModel;
use pas::util::rng::Pcg64;

fn main() {
    println!("== pas_overhead: PCA correction vs one NFE ==");
    let mut rng = Pcg64::seed(3);
    for dim in [64usize, 256, 4096] {
        // Buffer shaped like a 10-NFE run at its last step: 11 rows.
        let mut q = TrajBuffer::new(dim);
        for _ in 0..11 {
            q.push(&rng.normal_vec(dim));
        }
        let d = rng.normal_vec(dim);
        let r = harness::bench(&format!("pca_basis dim={dim} rows=12"), 10, 50, 0.3, || {
            harness::black_box(pca_basis(&q, &d, 4));
        });
        // One batched model eval on the matching analytic dataset.
        if dim == 64 {
            let ds = pas::data::registry::get("gmm-hd64").unwrap();
            let model = AnalyticEps::from_dataset(&ds);
            let n = 64;
            let x = rng.normal_vec(n * dim);
            let mut out = vec![0.0; n * dim];
            let m = harness::bench("analytic eval gmm-hd64 b64 (1 NFE)", 3, 20, 0.3, || {
                model.eval_batch(&x, n, 2.0, &mut out);
            });
            // Per-sample PCA vs per-sample NFE share.
            println!(
                "  -> PCA/NFE ratio (batch 64): {:.3}% (paper claims ~0.2%)",
                r.median_s * 64.0 / m.median_s * 100.0
            );
        }
    }

    // PJRT model eval if artifacts exist.
    let dir = pas::runtime::artifacts_dir();
    if dir.join("eps_gmm-hd64.hlo.txt").exists() {
        let rt = pas::runtime::Runtime::cpu().unwrap();
        let exe = rt.load_artifact(&dir, "eps_gmm-hd64").unwrap();
        let model = pas::score::pjrt::PjrtEps::new(exe);
        let n = 64;
        let x = rng.normal_vec(n * 64);
        let mut out = vec![0.0; n * 64];
        let m = harness::bench("pjrt eval eps_gmm-hd64 b64 (1 NFE)", 3, 20, 0.5, || {
            model.eval_batch(&x, n, 2.0, &mut out);
        });
        let mut q = TrajBuffer::new(64);
        for _ in 0..11 {
            q.push(&rng.normal_vec(64));
        }
        let d = rng.normal_vec(64);
        let r = harness::bench("pca_basis dim=64 rows=12", 10, 50, 0.3, || {
            harness::black_box(pca_basis(&q, &d, 4));
        });
        println!(
            "  -> PCA/PJRT-NFE ratio (batch 64): {:.3}%",
            r.median_s * 64.0 / m.median_s * 100.0
        );
    } else {
        println!("(artifacts missing; skipping PJRT comparison — run `make artifacts`)");
    }
}
