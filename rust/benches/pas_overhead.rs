//! Bench: the §3.5 cost claim — one PCA-based correction must be
//! negligible next to one model evaluation (paper: 0.06 s vs 30.2 s on
//! Stable Diffusion = 0.2 %). We measure the PCA basis + reconstruction
//! against (a) the analytic model and (b) the PJRT denoiser when
//! artifacts are present (requires the `pjrt` feature).
//!
//! Also verifies the engine's zero-allocation claim: a counting global
//! allocator measures heap allocations per step of a warmed
//! `SamplerEngine` in `Record::None` mode (the serving configuration) —
//! the steady state must be **zero**.

#[path = "harness.rs"]
mod harness;
#[path = "../tests/support/counting_alloc.rs"]
mod counting_alloc;

use counting_alloc::{CountingAlloc, ALLOC_COUNT};
use pas::pas::pca::{pca_basis, TrajBuffer};
use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::score::EpsModel;
use pas::solvers::engine::{Record, SamplerEngine};
use pas::traj::sample_prior;
use pas::util::rng::Pcg64;
use std::sync::atomic::Ordering;

#[global_allocator]
static ALLOCATOR: CountingAlloc = CountingAlloc;

/// Steady-state allocations per step of the serving path: warmed engine,
/// `Record::None`, 10 NFE on latent256 at batch 256 (the acceptance
/// configuration), across representative registry solvers — single-eval,
/// multi-eval (scratch-arena + sharded internal evals) and
/// history-hungry. Returns false (and the process exits non-zero) if any
/// steady state allocates — this is an enforced invariant, not a report.
/// `tests/alloc_audit.rs` covers the full registry × record-mode matrix.
#[must_use]
fn engine_steady_state_allocs() -> bool {
    println!("\n== engine steady-state allocations (Record::None, 10 NFE, latent256 b256) ==");
    let ds = pas::data::registry::get("latent256").unwrap();
    let model = AnalyticEps::from_dataset(&ds);
    let n = 256;
    let dim = ds.dim();
    let mut rng = Pcg64::seed(7);
    let mut engine = SamplerEngine::with_record(Record::None);
    let mut x0 = vec![0.0; n * dim];
    let mut all_zero = true;
    for solver_name in ["ddim", "dpm2", "unipc3m"] {
        let solver = pas::solvers::registry::get(solver_name).unwrap();
        let steps = solver.steps_for_nfe(10).unwrap();
        let sched = default_schedule(steps);
        let x_t = sample_prior(&mut rng, n, dim, sched.t_max());
        // Warm-up: sizes the engine workspace (node stores + solver
        // scratch arena) and every pool worker's thread-local eval
        // scratch (generous so no worker's lazy scratch init can land
        // inside the measured window).
        for _ in 0..10 {
            engine.run_into(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None, &mut x0);
        }
        let runs = 20usize;
        let before = ALLOC_COUNT.load(Ordering::SeqCst);
        for _ in 0..runs {
            engine.run_into(solver.as_ref(), model.as_ref(), &x_t, n, &sched, None, &mut x0);
        }
        let after = ALLOC_COUNT.load(Ordering::SeqCst);
        let total = after - before;
        println!(
            "{solver_name}: steady-state heap allocations: {total} over {} steps ({:.4}/step)",
            runs * steps,
            total as f64 / (runs * steps) as f64
        );
        if total == 0 {
            println!("  -> ZERO steady-state allocations per step (engine claim holds)");
        } else {
            println!("  -> FAIL: expected zero; the serving path regressed");
            all_zero = false;
        }
    }
    all_zero
}

fn main() {
    println!("== pas_overhead: PCA correction vs one NFE ==");
    let mut rng = Pcg64::seed(3);
    for dim in [64usize, 256, 4096] {
        // Buffer shaped like a 10-NFE run at its last step: 11 rows.
        let mut q = TrajBuffer::new(dim);
        for _ in 0..11 {
            q.push(&rng.normal_vec(dim));
        }
        let d = rng.normal_vec(dim);
        let r = harness::bench(&format!("pca_basis dim={dim} rows=12"), 10, 50, 0.3, || {
            harness::black_box(pca_basis(&q, &d, 4));
        });
        // One batched model eval on the matching analytic dataset.
        if dim == 64 {
            let ds = pas::data::registry::get("gmm-hd64").unwrap();
            let model = AnalyticEps::from_dataset(&ds);
            let n = 64;
            let x = rng.normal_vec(n * dim);
            let mut out = vec![0.0; n * dim];
            let m = harness::bench("analytic eval gmm-hd64 b64 (1 NFE)", 3, 20, 0.3, || {
                model.eval_batch(&x, n, 2.0, &mut out);
            });
            // Per-sample PCA vs per-sample NFE share.
            println!(
                "  -> PCA/NFE ratio (batch 64): {:.3}% (paper claims ~0.2%)",
                r.median_s * 64.0 / m.median_s * 100.0
            );
        }
    }

    let zero_alloc = engine_steady_state_allocs();

    pjrt_comparison(&mut rng);

    if !zero_alloc {
        std::process::exit(1);
    }
}

#[cfg(feature = "pjrt")]
fn pjrt_comparison(rng: &mut Pcg64) {
    // PJRT model eval if artifacts exist.
    let dir = pas::runtime::artifacts_dir();
    if dir.join("eps_gmm-hd64.hlo.txt").exists() {
        let rt = pas::runtime::Runtime::cpu().unwrap();
        let exe = rt.load_artifact(&dir, "eps_gmm-hd64").unwrap();
        let model = pas::score::pjrt::PjrtEps::new(exe);
        let n = 64;
        let x = rng.normal_vec(n * 64);
        let mut out = vec![0.0; n * 64];
        let m = harness::bench("pjrt eval eps_gmm-hd64 b64 (1 NFE)", 3, 20, 0.5, || {
            model.eval_batch(&x, n, 2.0, &mut out);
        });
        let mut q = TrajBuffer::new(64);
        for _ in 0..11 {
            q.push(&rng.normal_vec(64));
        }
        let d = rng.normal_vec(64);
        let r = harness::bench("pca_basis dim=64 rows=12", 10, 50, 0.3, || {
            harness::black_box(pca_basis(&q, &d, 4));
        });
        println!(
            "  -> PCA/PJRT-NFE ratio (batch 64): {:.3}%",
            r.median_s * 64.0 / m.median_s * 100.0
        );
    } else {
        println!("(artifacts missing; skipping PJRT comparison — run `make artifacts`)");
    }
}

#[cfg(not(feature = "pjrt"))]
fn pjrt_comparison(_rng: &mut Pcg64) {
    println!("(built without the `pjrt` feature; skipping PJRT comparison)");
}
