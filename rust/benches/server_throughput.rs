//! Bench: sampling-service throughput and batching efficiency under a
//! concurrent open loop (L3 serving path).

use pas::server::{SamplingRequest, Service, ServiceConfig};
use std::sync::atomic::Ordering;
use std::time::{Duration, Instant};

fn run_load(workers: usize, requests: usize, n_per_req: usize) {
    let svc = Service::start(
        ServiceConfig {
            workers,
            max_batch: 512,
            batch_window: Duration::from_millis(2),
            queue_depth: 1024,
            ..ServiceConfig::default()
        },
        Vec::new(),
    );
    let t = Instant::now();
    let rxs: Vec<_> = (0..requests)
        .filter_map(|i| {
            svc.submit(SamplingRequest {
                id: 0,
                dataset: "gmm-hd64".into(),
                solver: "ddim".into(),
                nfe: 10,
                n_samples: n_per_req,
                seed: i as u64,
                use_pas: false,
                deadline_ms: None,
                priority: 0,
            })
            .ok()
        })
        .collect();
    let accepted = rxs.len();
    let mut samples = 0usize;
    for rx in rxs {
        if let Ok(r) = rx.recv() {
            if r.error.is_none() {
                samples += r.n;
            }
        }
    }
    let wall = t.elapsed().as_secs_f64();
    let batches = svc.metrics.batches.load(Ordering::Relaxed);
    println!(
        "workers={workers:<2} reqs={requests} accepted={accepted} samples={samples} \
         wall={:.2}s -> {:.0} samples/s, {:.1} reqs/batch",
        wall,
        samples as f64 / wall,
        accepted as f64 / batches.max(1) as f64
    );
    svc.shutdown();
}

fn main() {
    println!("== server_throughput (gmm-hd64, ddim@10, 16 samples/req) ==");
    for workers in [1usize, 2, 4, 8] {
        run_load(workers, 128, 16);
    }
}
