//! Bench: per-step cost of every solver on the analytic models — the L3
//! compute hot path (analytic eps eval dominates; see EXPERIMENTS.md §Perf).

#[path = "harness.rs"]
mod harness;

use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::solvers::{registry, run_solver};
use pas::traj::sample_prior;
use pas::util::rng::Pcg64;

fn main() {
    println!("== solver_step: full 10-NFE sampling run, batch 256 ==");
    for ds_name in ["gmm2d", "gmm-hd64", "latent256"] {
        let ds = pas::data::registry::get(ds_name).unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let mut rng = Pcg64::seed(1);
        let n = 256;
        for solver_name in ["ddim", "ipndm", "dpmpp3m", "unipc3m", "deis-tab3"] {
            let solver = registry::get(solver_name).unwrap();
            let steps = solver.steps_for_nfe(10).unwrap();
            let sched = default_schedule(steps);
            let x_t = sample_prior(&mut rng, n, ds.dim(), sched.t_max());
            harness::bench(
                &format!("{ds_name}/{solver_name} 10NFE b{n}"),
                1,
                5,
                0.5,
                || {
                    harness::black_box(run_solver(
                        solver.as_ref(),
                        model.as_ref(),
                        &x_t,
                        n,
                        &sched,
                        None,
                    ));
                },
            );
        }
    }
    // Raw model eval throughput (the inner hot loop).
    println!("\n== analytic eps eval, batch 256 ==");
    for ds_name in ["gmm2d", "gmm-hd64", "latent256"] {
        let ds = pas::data::registry::get(ds_name).unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let mut rng = Pcg64::seed(2);
        let n = 256;
        let x = sample_prior(&mut rng, n, ds.dim(), 10.0);
        let mut out = vec![0.0; n * ds.dim()];
        use pas::score::EpsModel;
        harness::bench(&format!("{ds_name}/eval b{n}"), 3, 20, 0.5, || {
            model.eval_batch(&x, n, 2.0, &mut out);
            harness::black_box(&out);
        });
    }
}
