//! Bench: full sampling runs on the analytic models — the L3 compute hot
//! path — comparing the seed's allocate-per-step driver
//! (`run_solver_legacy`) against the workspace-pooled [`SamplerEngine`]
//! in its serving configuration (`Record::None`, pooled row-sharding),
//! swept across every kernel backend the hardware supports (scalar
//! always; avx2 / avx2fma where detected).
//!
//! Emits `BENCH_solver_step.json` (cwd) with per-cell medians and
//! speedups — each cell tagged with its `backend` — so the perf
//! trajectory is tracked across PRs and backends.

#[path = "harness.rs"]
mod harness;

use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::score::EpsModel;
use pas::solvers::engine::{Record, SamplerEngine};
use pas::solvers::{registry, run_solver_legacy};
use pas::tensor::gemm::{force_backend, simd_available, Backend};
use pas::traj::sample_prior;
use pas::util::json::Json;
use pas::util::rng::Pcg64;

fn main() {
    let mut backends = vec![Backend::Scalar];
    if simd_available() {
        backends.push(Backend::Avx2);
        backends.push(Backend::Avx2Fma);
    } else {
        println!("note: CPU lacks avx2+fma; sweeping the scalar backend only");
    }
    let mut cells: Vec<Json> = Vec::new();
    println!("== solver_step: full 10-NFE sampling run, batch 256 ==");
    println!("   (legacy = seed allocate-per-step driver, engine = Record::None workspace)");
    for &be in &backends {
        let active = force_backend(be);
        println!("-- kernel backend: {} --", active.name());
        for ds_name in ["gmm2d", "gmm-hd64", "latent256"] {
            let ds = pas::data::registry::get(ds_name).unwrap();
            let model = AnalyticEps::from_dataset(&ds);
            let mut rng = Pcg64::seed(1);
            let n = 256;
            let dim = ds.dim();
            // Sweep every registered solver (multi-eval solvers included
            // since the engine row-shards their internal evals too). The
            // "ipndm" alias is skipped: it resolves to the same solver as
            // ipndm3 and would double-count that cell.
            for &solver_name in registry::ALL.iter().filter(|&&s| s != "ipndm") {
                let solver = registry::get(solver_name).unwrap();
                let steps = solver.steps_for_nfe(10).unwrap();
                let sched = default_schedule(steps);
                let x_t = sample_prior(&mut rng, n, dim, sched.t_max());
                let legacy = harness::bench(
                    &format!("[{}] {ds_name}/{solver_name} 10NFE b{n} legacy", active.name()),
                    1,
                    5,
                    0.5,
                    || {
                        harness::black_box(run_solver_legacy(
                            solver.as_ref(),
                            model.as_ref(),
                            &x_t,
                            n,
                            &sched,
                            None,
                        ));
                    },
                );
                let mut engine = SamplerEngine::with_record(Record::None);
                let mut x0 = vec![0.0; n * dim];
                let engined = harness::bench(
                    &format!("[{}] {ds_name}/{solver_name} 10NFE b{n} engine", active.name()),
                    1,
                    5,
                    0.5,
                    || {
                        engine.run_into(
                            solver.as_ref(),
                            model.as_ref(),
                            &x_t,
                            n,
                            &sched,
                            None,
                            &mut x0,
                        );
                        harness::black_box(&x0);
                    },
                );
                let speedup = legacy.median_s / engined.median_s;
                println!("  -> engine speedup vs legacy driver: {speedup:.2}x");
                let mut cell = Json::obj();
                cell.set("backend", Json::Str(active.name().into()))
                    .set("dataset", Json::Str(ds_name.into()))
                    .set("solver", Json::Str(solver_name.into()))
                    .set("nfe", Json::Num(10.0))
                    .set("batch", Json::Num(n as f64))
                    .set("legacy_median_s", Json::Num(legacy.median_s))
                    .set("engine_median_s", Json::Num(engined.median_s))
                    .set("speedup", Json::Num(speedup));
                cells.push(cell);
            }
        }
    }
    // Raw model eval throughput (the inner hot loop), per backend.
    println!("\n== analytic eps eval, batch 256 ==");
    for &be in &backends {
        let active = force_backend(be);
        for ds_name in ["gmm2d", "gmm-hd64", "latent256"] {
            let ds = pas::data::registry::get(ds_name).unwrap();
            let model = AnalyticEps::from_dataset(&ds);
            let mut rng = Pcg64::seed(2);
            let n = 256;
            let x = sample_prior(&mut rng, n, ds.dim(), 10.0);
            let mut out = vec![0.0; n * ds.dim()];
            let r = harness::bench(
                &format!("[{}] {ds_name}/eval b{n}", active.name()),
                3,
                20,
                0.5,
                || {
                    model.eval_batch(&x, n, 2.0, &mut out);
                    harness::black_box(&out);
                },
            );
            let mut cell = Json::obj();
            cell.set("backend", Json::Str(active.name().into()))
                .set("dataset", Json::Str(ds_name.into()))
                .set("kind", Json::Str("raw_eval".into()))
                .set("batch", Json::Num(n as f64))
                .set("eval_median_s", Json::Num(r.median_s));
            cells.push(cell);
        }
    }
    let mut top = Json::obj();
    top.set("bench", Json::Str("solver_step".into()))
        .set("threads", Json::Num(pas::util::pool::Pool::global().size() as f64))
        .set(
            "backends",
            Json::Arr(
                backends
                    .iter()
                    .map(|b| Json::Str(b.name().into()))
                    .collect(),
            ),
        )
        .set("results", Json::Arr(cells));
    match std::fs::write("BENCH_solver_step.json", top.to_string()) {
        Ok(()) => println!("\nwrote BENCH_solver_step.json"),
        Err(e) => eprintln!("\ncould not write BENCH_solver_step.json: {e}"),
    }
}
