//! Bench: PJRT executable throughput (the request-path model eval) and
//! end-to-end sampling on the AOT-compiled denoiser.

#[path = "harness.rs"]
mod harness;

use pas::schedule::default_schedule;
use pas::score::pjrt::PjrtEps;
use pas::score::EpsModel;
use pas::solvers::{registry, run_solver};
use pas::traj::sample_prior;
use pas::util::rng::Pcg64;

fn main() {
    let dir = pas::runtime::artifacts_dir();
    if !dir.join("eps_gmm-hd64.hlo.txt").exists() {
        println!("artifacts missing — run `make artifacts` first; skipping pjrt_eval");
        return;
    }
    let rt = pas::runtime::Runtime::cpu().unwrap();
    println!("== pjrt_eval on {} ==", rt.platform());
    for name in ["eps_spiral2d", "eps_gmm-hd64"] {
        let exe = rt.load_artifact(&dir, name).unwrap();
        let model = PjrtEps::new(exe);
        let (b, d) = (model.batch(), model.dim());
        let mut rng = Pcg64::seed(5);
        let x = rng.normal_vec(b * d);
        let mut out = vec![0.0; b * d];
        harness::bench(&format!("{name} eval b{b}"), 3, 20, 0.5, || {
            model.eval_batch(&x, b, 2.0, &mut out);
            harness::black_box(&out);
        });
        // Padding path: n not a multiple of the compiled batch.
        let x_small = rng.normal_vec(10 * d);
        let mut out_small = vec![0.0; 10 * d];
        harness::bench(&format!("{name} eval n=10 (padded to b{b})"), 3, 20, 0.5, || {
            model.eval_batch(&x_small, 10, 2.0, &mut out_small);
            harness::black_box(&out_small);
        });
    }
    // End-to-end sampling run on the PJRT model.
    let exe = rt.load_artifact(&dir, "eps_gmm-hd64").unwrap();
    let model = PjrtEps::new(exe);
    let solver = registry::get("ddim").unwrap();
    let sched = default_schedule(10);
    let mut rng = Pcg64::seed(6);
    let n = model.batch();
    let x_t = sample_prior(&mut rng, n, model.dim(), sched.t_max());
    harness::bench("ddim 10NFE on pjrt eps_gmm-hd64 b64", 1, 3, 1.0, || {
        harness::black_box(run_solver(
            solver.as_ref(),
            &model,
            &x_t,
            n,
            &sched,
            None,
        ));
    });
}
