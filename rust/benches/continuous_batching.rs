//! Bench: serving-path tail latency under **staggered arrivals** —
//! step-level continuous batching vs the collect-then-run baseline.
//!
//! Open-loop load: requests for one compatibility key arrive at a fixed
//! interval calibrated to a fraction of one solo rollout, so most
//! arrivals land while earlier requests are mid-flight. The
//! collect-then-run batcher can only fuse requests that arrive inside its
//! batch window; everything else waits a full rollout behind the running
//! batch, so its p99 is bounded by *batch duration*. The continuous
//! scheduler admits at step boundaries, so its p99 is bounded by *step
//! duration* plus the shared-tick slowdown.
//!
//! Emits `BENCH_serve.json` (cwd) with per-mode latency percentiles and
//! throughput at the same offered load, plus a `staging_cut` section
//! recording the per-solver history windows the continuous scheduler now
//! stages (`hist_depth()+2` x-nodes / `+1` d-nodes vs the old fixed
//! `HIST_NODES` copy) and a measured depth-0 (ddim) continuous run.

use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::score::EpsModel;
use pas::server::{Batching, SamplingRequest, Service, ServiceConfig};
use pas::solvers::engine::{Record, SamplerEngine};
use pas::traj::sample_prior_stream;
use pas::util::json::Json;
use std::time::{Duration, Instant};

const DATASET: &str = "gmm-hd64";
const SOLVER: &str = "dpmpp3m";
const NFE: usize = 24;
const N_PER_REQ: usize = 64;
const REQUESTS: usize = 24;

struct ModeStats {
    p50_ms: f64,
    p95_ms: f64,
    p99_ms: f64,
    mean_ms: f64,
    mean_queue_ms: f64,
    samples_per_s: f64,
    batches: u64,
}

fn percentile(sorted: &[f64], p: f64) -> f64 {
    let idx = ((sorted.len() as f64 - 1.0) * p).round() as usize;
    sorted[idx.min(sorted.len() - 1)]
}

/// One solo rollout on the serving engine, for arrival-rate calibration.
fn calibrate_solo_ms(solver_name: &str) -> f64 {
    let ds = pas::data::registry::get(DATASET).unwrap();
    let model = AnalyticEps::from_dataset(&ds);
    let solver = pas::solvers::registry::get(solver_name).unwrap();
    let steps = solver.steps_for_nfe(NFE).unwrap();
    let sched = default_schedule(steps);
    let dim = model.dim();
    let x_t = sample_prior_stream(1, 1, N_PER_REQ, dim, sched.t_max());
    let mut x0 = vec![0.0; N_PER_REQ * dim];
    let mut engine = SamplerEngine::with_record(Record::None);
    // Warm the workspace, then time the steady state.
    engine.run_into(solver.as_ref(), model.as_ref(), &x_t, N_PER_REQ, &sched, None, &mut x0);
    let t = Instant::now();
    let reps = 3;
    for _ in 0..reps {
        engine.run_into(solver.as_ref(), model.as_ref(), &x_t, N_PER_REQ, &sched, None, &mut x0);
    }
    t.elapsed().as_secs_f64() * 1e3 / reps as f64
}

fn run_mode(solver_name: &str, batching: Batching, interval: Duration) -> ModeStats {
    let svc = Service::start(
        ServiceConfig {
            workers: 1, // one worker: scheduling policy, not parallelism, decides
            max_batch: 4096,
            batch_window: Duration::from_millis(2),
            queue_depth: 1024,
            batching,
            engine_threads: 0,
            artifact_root: None,
        },
        Vec::new(),
    );
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..REQUESTS {
        let target = interval * i as u32;
        let now = t0.elapsed();
        if now < target {
            std::thread::sleep(target - now);
        }
        rxs.push(
            svc.submit(SamplingRequest {
                id: 0,
                dataset: DATASET.into(),
                solver: solver_name.into(),
                nfe: NFE,
                n_samples: N_PER_REQ,
                seed: i as u64,
                use_pas: false,
                deadline_ms: None,
                priority: 0,
            })
            .expect("queue deep enough for the whole load"),
        );
    }
    let mut lats = Vec::new();
    let mut queues = Vec::new();
    let mut samples = 0usize;
    for rx in rxs {
        let r = rx.recv().expect("worker alive");
        assert!(r.error.is_none(), "{:?}", r.error);
        lats.push(r.latency_ms);
        queues.push(r.queue_ms);
        samples += r.n;
    }
    let wall = t0.elapsed().as_secs_f64();
    let batches = svc.metrics.batches.load(std::sync::atomic::Ordering::Relaxed);
    svc.shutdown();
    lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    ModeStats {
        p50_ms: percentile(&lats, 0.50),
        p95_ms: percentile(&lats, 0.95),
        p99_ms: percentile(&lats, 0.99),
        mean_ms: lats.iter().sum::<f64>() / lats.len() as f64,
        mean_queue_ms: queues.iter().sum::<f64>() / queues.len() as f64,
        samples_per_s: samples as f64 / wall,
        batches,
    }
}

// ---------------------------------------------------------------------------
// Overload + mixed-priority scenario (SLO admission control)
// ---------------------------------------------------------------------------

const OVERLOAD_REQUESTS: usize = 48;

struct PriorityStats {
    completed: usize,
    shed: usize,
    mean_latency_ms: f64,
}

struct OverloadStats {
    deadline_mult: f64,
    deadline_ms: f64,
    completed: usize,
    shed: usize,
    shed_rate: f64,
    admitted_p50_ms: f64,
    admitted_p99_ms: f64,
    /// Mean latency of *shed* replies — how fast infeasible requests
    /// fail (the whole point of shedding vs queue-to-death).
    shed_reply_mean_ms: f64,
    by_priority: [PriorityStats; 2],
}

/// Offered load ~1.5x capacity on one key, every request carrying
/// `deadline_ms = deadline_mult x solo`, priorities alternating 0 / 5.
/// Tight deadlines should shed the tail fast and keep admitted p99
/// bounded near the deadline; loose deadlines shed little and let p99
/// grow with the queue — the shed-rate vs p99 tradeoff BENCH_serve.json
/// reports.
fn run_overload(deadline_mult: f64, solo_ms: f64) -> OverloadStats {
    let svc = Service::start(
        ServiceConfig {
            workers: 1,
            // 4 requests co-resident: arrivals at 6/solo overrun capacity,
            // so a queue actually builds and deadlines start binding.
            max_batch: 4 * N_PER_REQ,
            batch_window: Duration::from_millis(2),
            queue_depth: 1024,
            batching: Batching::Continuous,
            engine_threads: 0,
            artifact_root: None,
        },
        Vec::new(),
    );
    let deadline_ms = solo_ms * deadline_mult;
    let interval = Duration::from_secs_f64(solo_ms / 6.0 / 1e3);
    let t0 = Instant::now();
    let mut rxs = Vec::new();
    for i in 0..OVERLOAD_REQUESTS {
        let target = interval * i as u32;
        let now = t0.elapsed();
        if now < target {
            std::thread::sleep(target - now);
        }
        let priority = if i % 2 == 1 { 5 } else { 0 };
        rxs.push((
            priority,
            svc.submit(SamplingRequest {
                id: 0,
                dataset: DATASET.into(),
                solver: SOLVER.into(),
                nfe: NFE,
                n_samples: N_PER_REQ,
                seed: i as u64,
                use_pas: false,
                deadline_ms: Some(deadline_ms),
                priority,
            })
            .expect("queue deep enough for the whole load"),
        ));
    }
    let mut admitted_lats = Vec::new();
    let mut shed_lats = Vec::new();
    let mut by_priority = [
        PriorityStats { completed: 0, shed: 0, mean_latency_ms: 0.0 },
        PriorityStats { completed: 0, shed: 0, mean_latency_ms: 0.0 },
    ];
    for (priority, rx) in rxs {
        let r = rx.recv().expect("worker alive");
        let slot = usize::from(priority != 0);
        match &r.error {
            None => {
                admitted_lats.push(r.latency_ms);
                by_priority[slot].completed += 1;
                by_priority[slot].mean_latency_ms += r.latency_ms;
            }
            Some(e) => {
                assert!(e.contains("deadline"), "unexpected serve error: {e}");
                assert!(r.latency_ms > 0.0, "shed replies must carry real latency");
                shed_lats.push(r.latency_ms);
                by_priority[slot].shed += 1;
            }
        }
    }
    svc.shutdown();
    for p in by_priority.iter_mut() {
        if p.completed > 0 {
            p.mean_latency_ms /= p.completed as f64;
        }
    }
    admitted_lats.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let (p50, p99) = if admitted_lats.is_empty() {
        (0.0, 0.0)
    } else {
        (percentile(&admitted_lats, 0.50), percentile(&admitted_lats, 0.99))
    };
    OverloadStats {
        deadline_mult,
        deadline_ms,
        completed: admitted_lats.len(),
        shed: shed_lats.len(),
        shed_rate: shed_lats.len() as f64 / OVERLOAD_REQUESTS as f64,
        admitted_p50_ms: p50,
        admitted_p99_ms: p99,
        shed_reply_mean_ms: if shed_lats.is_empty() {
            0.0
        } else {
            shed_lats.iter().sum::<f64>() / shed_lats.len() as f64
        },
        by_priority,
    }
}

fn overload_json(s: &OverloadStats) -> Json {
    let prio = |p: &PriorityStats| {
        let mut o = Json::obj();
        o.set("completed", Json::Num(p.completed as f64))
            .set("shed", Json::Num(p.shed as f64))
            .set("mean_latency_ms", Json::Num(p.mean_latency_ms));
        o
    };
    let mut o = Json::obj();
    o.set("deadline_mult", Json::Num(s.deadline_mult))
        .set("deadline_ms", Json::Num(s.deadline_ms))
        .set("requests", Json::Num(OVERLOAD_REQUESTS as f64))
        .set("completed", Json::Num(s.completed as f64))
        .set("shed", Json::Num(s.shed as f64))
        .set("shed_rate", Json::Num(s.shed_rate))
        .set("admitted_p50_ms", Json::Num(s.admitted_p50_ms))
        .set("admitted_p99_ms", Json::Num(s.admitted_p99_ms))
        .set("shed_reply_mean_ms", Json::Num(s.shed_reply_mean_ms))
        .set("priority_0", prio(&s.by_priority[0]))
        .set("priority_5", prio(&s.by_priority[1]));
    o
}

fn print_overload(s: &OverloadStats) {
    println!(
        "overload x{:<4.1} shed {:>2}/{} ({:>5.1}%)  admitted p50 {:>8.2} ms  p99 {:>8.2} ms  \
         shed-reply mean {:>7.2} ms  prio5 {}/{} done  prio0 {}/{} done",
        s.deadline_mult,
        s.shed,
        OVERLOAD_REQUESTS,
        s.shed_rate * 100.0,
        s.admitted_p50_ms,
        s.admitted_p99_ms,
        s.shed_reply_mean_ms,
        s.by_priority[1].completed,
        s.by_priority[1].completed + s.by_priority[1].shed,
        s.by_priority[0].completed,
        s.by_priority[0].completed + s.by_priority[0].shed,
    );
}

fn stats_json(s: &ModeStats) -> Json {
    let mut o = Json::obj();
    o.set("p50_ms", Json::Num(s.p50_ms))
        .set("p95_ms", Json::Num(s.p95_ms))
        .set("p99_ms", Json::Num(s.p99_ms))
        .set("mean_ms", Json::Num(s.mean_ms))
        .set("mean_queue_ms", Json::Num(s.mean_queue_ms))
        .set("samples_per_s", Json::Num(s.samples_per_s))
        .set("batches", Json::Num(s.batches as f64));
    o
}

fn print_stats(name: &str, s: &ModeStats) {
    println!(
        "{name:<12} p50 {:>8.2} ms  p95 {:>8.2} ms  p99 {:>8.2} ms  mean {:>8.2} ms  \
         queue {:>8.2} ms  {:>9.0} samples/s  ({} batches)",
        s.p50_ms, s.p95_ms, s.p99_ms, s.mean_ms, s.mean_queue_ms, s.samples_per_s, s.batches
    );
}

fn main() {
    let solo_ms = calibrate_solo_ms(SOLVER);
    // Arrivals 3x faster than solo rollouts: sustained only by batching;
    // the two modes differ in *when* a late arrival can start.
    let interval = Duration::from_secs_f64(solo_ms / 3.0 / 1e3);
    println!(
        "== continuous_batching: {DATASET}/{SOLVER}@{NFE}, {REQUESTS} reqs x {N_PER_REQ} \
         samples, solo {solo_ms:.2} ms, arrival interval {:.2} ms ==",
        interval.as_secs_f64() * 1e3
    );
    // Collect-then-run first (cold pool warms up in calibration above).
    let collect = run_mode(SOLVER, Batching::CollectThenRun, interval);
    print_stats("collect", &collect);
    let continuous = run_mode(SOLVER, Batching::Continuous, interval);
    print_stats("continuous", &continuous);
    let p99_speedup = collect.p99_ms / continuous.p99_ms.max(1e-9);
    let thpt_ratio = continuous.samples_per_s / collect.samples_per_s.max(1e-9);
    println!(
        "p99 improvement (collect/continuous): {p99_speedup:.2}x at {thpt_ratio:.2}x relative \
         throughput"
    );

    let mut top = Json::obj();
    let mut workload = Json::obj();
    workload
        .set("dataset", Json::Str(DATASET.into()))
        .set("solver", Json::Str(SOLVER.into()))
        .set("nfe", Json::Num(NFE as f64))
        .set("n_per_request", Json::Num(N_PER_REQ as f64))
        .set("requests", Json::Num(REQUESTS as f64))
        .set("solo_run_ms", Json::Num(solo_ms))
        .set("arrival_interval_ms", Json::Num(interval.as_secs_f64() * 1e3))
        .set(
            "pas_threads",
            Json::Str(std::env::var("PAS_THREADS").unwrap_or_else(|_| "auto".into())),
        );
    // Overload scenarios: same key at ~1.5x capacity, mixed priorities,
    // tight vs loose deadlines — the shed-rate vs admitted-p99 tradeoff.
    println!(
        "== overload: {OVERLOAD_REQUESTS} reqs at 6x solo rate, priorities 0/5 alternating =="
    );
    let tight = run_overload(2.0, solo_ms);
    print_overload(&tight);
    let loose = run_overload(16.0, solo_ms);
    print_overload(&loose);
    if tight.shed == 0 {
        eprintln!(
            "WARNING: tight-deadline overload scenario shed nothing on this machine/run \
             (deadline {:.2} ms)",
            tight.deadline_ms
        );
    }

    // History-staging cut: per-solver, the continuous scheduler now
    // stages hist_depth()+2 x-nodes and hist_depth()+1 d-nodes per tick
    // instead of the fixed HIST_NODES / HIST_NODES−1 windows. Record the
    // window sizes plus a measured continuous-mode run on a depth-0
    // solver (ddim — the maximal cut) next to the default dpmpp3m run
    // above, so the staging delta lands in the artifact.
    let staging_cut = {
        use pas::solvers::engine::HIST_NODES;
        let mut arr: Vec<Json> = Vec::new();
        for name in ["ddim", SOLVER] {
            let depth = pas::solvers::registry::get(name).unwrap().hist_depth();
            let mut o = Json::obj();
            o.set("solver", Json::Str(name.into()))
                .set("hist_depth", Json::Num(depth as f64))
                .set("staged_x_nodes", Json::Num((depth + 2) as f64))
                .set("staged_d_nodes", Json::Num((depth + 1) as f64))
                .set("full_window_x_nodes", Json::Num(HIST_NODES as f64))
                .set("full_window_d_nodes", Json::Num((HIST_NODES - 1) as f64));
            arr.push(o);
        }
        let ddim_solo_ms = calibrate_solo_ms("ddim");
        let ddim_interval = Duration::from_secs_f64(ddim_solo_ms / 3.0 / 1e3);
        let ddim_cont = run_mode("ddim", Batching::Continuous, ddim_interval);
        print_stats("ddim cont", &ddim_cont);
        let mut o = Json::obj();
        o.set("windows", Json::Arr(arr))
            .set("ddim_solo_run_ms", Json::Num(ddim_solo_ms))
            .set(
                "ddim_arrival_interval_ms",
                Json::Num(ddim_interval.as_secs_f64() * 1e3),
            )
            .set("ddim_continuous", stats_json(&ddim_cont));
        o
    };

    top.set("workload", workload)
        .set("collect_then_run", stats_json(&collect))
        .set("continuous", stats_json(&continuous))
        .set("p99_improvement", Json::Num(p99_speedup))
        .set("throughput_ratio", Json::Num(thpt_ratio))
        .set("staging_cut", staging_cut)
        .set(
            "overload",
            Json::Arr(vec![overload_json(&tight), overload_json(&loose)]),
        );
    match std::fs::write("BENCH_serve.json", top.to_string()) {
        Ok(()) => println!("wrote BENCH_serve.json"),
        Err(e) => eprintln!("could not write BENCH_serve.json: {e}"),
    }
    if p99_speedup < 1.0 {
        eprintln!(
            "WARNING: continuous p99 ({:.2} ms) did not beat collect-then-run ({:.2} ms) on \
             this machine/run",
            continuous.p99_ms, collect.p99_ms
        );
    }
}
