//! Bench: metric evaluation cost (gFID's sqrtm dominates at D=256).

#[path = "harness.rs"]
mod harness;

use pas::metrics::{gfid, mmd2_rbf, sliced_w2};
use pas::util::rng::Pcg64;

fn main() {
    println!("== metrics_cost ==");
    let mut rng = Pcg64::seed(9);
    for dim in [2usize, 64, 256] {
        let n = 2048;
        let a = rng.normal_vec(n * dim);
        let b = rng.normal_vec(n * dim);
        harness::bench(&format!("gfid n={n} dim={dim}"), 1, 3, 0.5, || {
            harness::black_box(gfid(&a, n, &b, n, dim));
        });
        harness::bench(&format!("sliced_w2 n={n} dim={dim}"), 1, 3, 0.3, || {
            harness::black_box(sliced_w2(&a, n, &b, n, dim, 32, 1));
        });
        harness::bench(&format!("mmd2 n={n} dim={dim}"), 1, 3, 0.3, || {
            harness::black_box(mmd2_rbf(&a, n, &b, n, dim));
        });
    }
}
