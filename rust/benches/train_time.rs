//! Bench: PAS training wall-clock — the paper's "sub-minute training"
//! practicality claim, tracked per PR.
//!
//! Compares the workspace-pooled, sharded [`TrainSession`] against
//! [`PasTrainer::train_tp_reference`] — the pre-session sequential
//! monolith kept as the bitwise oracle (nested rollout rows, a fresh
//! allocating `Basis` per sample per step, single-threaded SGD). Reports
//! total train time for both paths plus the session's wall-clock **per
//! time point**, and writes `BENCH_train.json` (uploaded as a CI artifact
//! from both `PAS_THREADS` matrix legs; the multi-core leg is the
//! acceptance cell — the session must hold ≥ 2× total).
//!
//! The two paths train bit-identical dictionaries (asserted here too, so
//! the speedup is never quoted over diverging work).

// Only `fmt` is used from the shared harness (runs here are one-shot
// wall-clock measurements, not repeated micro-iterations).
#[path = "harness.rs"]
#[allow(dead_code)]
mod harness;

use pas::pas::train::{PasTrainer, TrainConfig, TrainSession};
use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::util::json::Json;
use pas::util::timer::Timer;

struct Case {
    dataset: &'static str,
    solver: &'static str,
    n_steps: usize,
    n_traj: usize,
    epochs: usize,
    minibatch: usize,
}

fn main() {
    let threads = pas::util::pool::Pool::global().size();
    println!("== PAS training wall-clock: TrainSession vs sequential reference (threads = {threads}) ==");
    let cases = [
        Case {
            dataset: "gmm-hd64",
            solver: "ddim",
            n_steps: 8,
            n_traj: 512,
            epochs: 48,
            minibatch: 128,
        },
        Case {
            dataset: "latent256",
            solver: "ddim",
            n_steps: 6,
            n_traj: 128,
            epochs: 24,
            minibatch: 64,
        },
    ];
    let mut cells: Vec<Json> = Vec::new();
    for case in &cases {
        let ds = pas::data::registry::get(case.dataset).unwrap();
        let model = AnalyticEps::from_dataset(&ds);
        let solver = pas::solvers::registry::get(case.solver).unwrap();
        let sched = default_schedule(case.n_steps);
        let cfg = TrainConfig {
            n_traj: case.n_traj,
            epochs: case.epochs,
            minibatch: case.minibatch,
            teacher_nfe: 100,
            ..TrainConfig::default()
        };

        // Session: one cold run to size the workspaces, then the measured
        // steady-state run with per-time-point instrumentation.
        let mut session = TrainSession::new(cfg.clone());
        session
            .train(solver.as_ref(), model.as_ref(), &sched, case.dataset, false, None)
            .expect("warm-up training run");
        let t_total = Timer::start();
        session
            .begin(solver.as_ref(), model.as_ref(), &sched, case.dataset, false, None)
            .expect("begin");
        let mut per_tp = Vec::with_capacity(case.n_steps);
        for j in 0..case.n_steps {
            let t = Timer::start();
            session
                .train_step(solver.as_ref(), model.as_ref(), &sched, j)
                .expect("train_step");
            per_tp.push(t.elapsed_s());
        }
        let session_result = session.finish();
        let s_session = t_total.elapsed_s();

        // Reference: the pre-refactor sequential path.
        let t_ref = Timer::start();
        let ref_result = PasTrainer::new(cfg)
            .train_tp_reference(solver.as_ref(), model.as_ref(), &sched, case.dataset, false, None)
            .expect("reference training run");
        let s_ref = t_ref.elapsed_s();

        assert_eq!(
            session_result.dict.steps, ref_result.dict.steps,
            "{}: session and reference must train identical dicts",
            case.dataset
        );

        let speedup = s_ref / s_session;
        println!(
            "{:<28} session {:>9}  reference {:>9}  ({speedup:.2}x, {} corrected steps)",
            format!("{} {}@{}", case.dataset, case.solver, case.n_steps),
            harness::fmt(s_session),
            harness::fmt(s_ref),
            session_result.dict.steps.len(),
        );
        for (j, s) in per_tp.iter().enumerate() {
            println!("    t{:<2} {:>9}/tp", case.n_steps - j, harness::fmt(*s));
        }
        if threads > 1 && speedup < 2.0 {
            println!(
                "    WARNING: speedup {speedup:.2}x below the 2x multi-core target \
                 (machine-dependent; see BENCH_train.json artifact)"
            );
        }

        let mut cell = Json::obj();
        cell.set("dataset", Json::Str(case.dataset.into()))
            .set("solver", Json::Str(case.solver.into()))
            .set("n_steps", Json::Num(case.n_steps as f64))
            .set("n_traj", Json::Num(case.n_traj as f64))
            .set("epochs", Json::Num(case.epochs as f64))
            .set("minibatch", Json::Num(case.minibatch as f64))
            .set("seconds_session_total", Json::Num(s_session))
            .set("seconds_reference_total", Json::Num(s_ref))
            .set("speedup", Json::Num(speedup))
            .set("seconds_per_time_point", Json::from_f64_slice(&per_tp));
        cells.push(cell);
    }
    let mut top = Json::obj();
    top.set("bench", Json::Str("train_time".into()))
        .set("threads", Json::Num(threads as f64))
        .set("results", Json::Arr(cells));
    match std::fs::write("BENCH_train.json", top.to_string()) {
        Ok(()) => println!("\nwrote BENCH_train.json"),
        Err(e) => eprintln!("\ncould not write BENCH_train.json: {e}"),
    }
}
