//! Bench: raw model-eval throughput — the cost every one of the paper's
//! tables is bottlenecked by (NFE × model-eval time dominates sampling;
//! PAS's premise is that its ~10-parameter correction is negligible next
//! to it). Reports **rows/sec** of [`AnalyticEps::eval_batch`] (the
//! sample-blocked GEMM pipeline) against
//! [`AnalyticEps::eval_batch_per_sample`] (the pre-blocking per-sample
//! path, same pool fan-out), across data dimensions {2, 64, 256} × mode
//! counts × batch sizes — and now across **kernel backends**: every cell
//! is measured once per backend the hardware supports (scalar always;
//! avx2 and the opt-in avx2fma tier where detected), selected via
//! `force_backend` so one process sweeps them all.
//!
//! CI runs this in both `PAS_THREADS` matrix legs {1, 4} and uploads
//! `BENCH_eval_batch.json` as an artifact alongside
//! `BENCH_solver_step.json`; the d=256 low-rank workload (latent256) at
//! PAS_THREADS=4 is the acceptance cell — the blocked pipeline must hold
//! ≥ 2× rows/sec over the per-sample path there, with no regression at
//! d=2. The backend sweep adds a second acceptance surface: the
//! `avx2_vs_scalar_dim64` summary must show ≥ 1.5× blocked rows/sec at
//! dim ≥ 64 on AVX2 hardware.

#[path = "harness.rs"]
mod harness;

use pas::score::analytic::AnalyticEps;
use pas::score::EpsModel;
use pas::tensor::gemm::{force_backend, simd_available, Backend};
use pas::traj::sample_prior;
use pas::util::json::Json;
use pas::util::rng::Pcg64;

fn main() {
    let threads = pas::util::pool::Pool::global().size();
    let mut backends = vec![Backend::Scalar];
    if simd_available() {
        backends.push(Backend::Avx2);
        backends.push(Backend::Avx2Fma);
    } else {
        println!("note: CPU lacks avx2+fma; sweeping the scalar backend only");
    }
    let mut cells: Vec<Json> = Vec::new();
    // (backend, dataset, dim, modes, batch, blocked rows/s) — kept flat
    // for the avx2-vs-scalar summary below.
    let mut blocked_rows: Vec<(Backend, &'static str, usize, usize, usize, f64)> = Vec::new();
    println!("== analytic eval throughput: blocked GEMM pipeline vs per-sample (threads = {threads}) ==");
    for &be in &backends {
        let active = force_backend(be);
        println!("-- kernel backend: {} --", active.name());
        for ds_name in ["gmm2d", "gmm-hd64", "latent256"] {
            let ds = pas::data::registry::get(ds_name).unwrap();
            let dim = ds.dim();
            let all_modes = ds.spec.modes.len();
            // Mode-count axis: the full mixture and a single-mode slice of it
            // (same covariance structure, no softmax mixing work).
            for n_modes in [1usize, all_modes] {
                let model = AnalyticEps::new(
                    format!("{ds_name}[m{n_modes}]"),
                    ds.spec.modes[..n_modes].to_vec(),
                );
                for n in [64usize, 1024] {
                    let mut rng = Pcg64::seed(3);
                    let x = sample_prior(&mut rng, n, dim, 10.0);
                    let mut out = vec![0.0; n * dim];
                    let blocked = harness::bench(
                        &format!("[{}] {ds_name} d{dim} m{n_modes} b{n} blocked", active.name()),
                        3,
                        20,
                        0.4,
                        || {
                            model.eval_batch(&x, n, 2.0, &mut out);
                            harness::black_box(&out);
                        },
                    );
                    let scalar = harness::bench(
                        &format!(
                            "[{}] {ds_name} d{dim} m{n_modes} b{n} per-sample",
                            active.name()
                        ),
                        3,
                        20,
                        0.4,
                        || {
                            model.eval_batch_per_sample(&x, n, 2.0, &mut out);
                            harness::black_box(&out);
                        },
                    );
                    let rows_blocked = n as f64 / blocked.median_s;
                    let rows_scalar = n as f64 / scalar.median_s;
                    let speedup = rows_blocked / rows_scalar;
                    println!(
                        "  -> {rows_blocked:.3e} rows/s blocked vs {rows_scalar:.3e} per-sample ({speedup:.2}x)"
                    );
                    blocked_rows.push((be, ds_name, dim, n_modes, n, rows_blocked));
                    let mut cell = Json::obj();
                    cell.set("backend", Json::Str(active.name().into()))
                        .set("dataset", Json::Str(ds_name.into()))
                        .set("dim", Json::Num(dim as f64))
                        .set("modes", Json::Num(n_modes as f64))
                        .set("batch", Json::Num(n as f64))
                        .set("rows_per_s_blocked", Json::Num(rows_blocked))
                        .set("rows_per_s_per_sample", Json::Num(rows_scalar))
                        .set("speedup", Json::Num(speedup));
                    cells.push(cell);
                }
            }
        }
    }

    // avx2-vs-scalar summary at dim ≥ 64 (the SIMD acceptance surface):
    // per-cell blocked-rows ratio, recorded in the artifact so the
    // ≥ 1.5× claim is checkable even when CI hardware varies.
    let mut summary: Vec<Json> = Vec::new();
    if backends.contains(&Backend::Avx2) {
        println!("-- avx2 vs scalar, blocked rows/s at dim >= 64 --");
        for &(be, ds_name, dim, n_modes, n, avx2_rows) in &blocked_rows {
            if be != Backend::Avx2 || dim < 64 {
                continue;
            }
            let scalar_rows = blocked_rows
                .iter()
                .find(|&&(b, d, dd, m, bn, _)| {
                    b == Backend::Scalar && d == ds_name && dd == dim && m == n_modes && bn == n
                })
                .map(|&(_, _, _, _, _, r)| r)
                .expect("scalar leg runs first");
            let ratio = avx2_rows / scalar_rows;
            println!("  {ds_name} d{dim} m{n_modes} b{n}: {ratio:.2}x");
            let mut s = Json::obj();
            s.set("dataset", Json::Str(ds_name.into()))
                .set("dim", Json::Num(dim as f64))
                .set("modes", Json::Num(n_modes as f64))
                .set("batch", Json::Num(n as f64))
                .set("avx2_over_scalar_blocked", Json::Num(ratio));
            summary.push(s);
        }
    }

    let mut top = Json::obj();
    top.set("bench", Json::Str("eval_throughput".into()))
        .set("threads", Json::Num(threads as f64))
        .set(
            "backends",
            Json::Arr(
                backends
                    .iter()
                    .map(|b| Json::Str(b.name().into()))
                    .collect(),
            ),
        )
        .set("avx2_vs_scalar_dim64", Json::Arr(summary))
        .set("results", Json::Arr(cells));
    match std::fs::write("BENCH_eval_batch.json", top.to_string()) {
        Ok(()) => println!("\nwrote BENCH_eval_batch.json"),
        Err(e) => eprintln!("\ncould not write BENCH_eval_batch.json: {e}"),
    }
}
