//! Minimal bench harness (the offline vendor set has no criterion):
//! warmup + timed iterations, reporting median / mean / p95 per iteration.
//! Used by every `cargo bench` target.

use std::time::Instant;

pub struct BenchResult {
    pub name: String,
    pub iters: usize,
    pub median_s: f64,
    pub mean_s: f64,
    pub p95_s: f64,
}

impl BenchResult {
    pub fn print(&self) {
        println!(
            "{:<44} {:>10}/iter  mean {:>10}  p95 {:>10}  ({} iters)",
            self.name,
            fmt(self.median_s),
            fmt(self.mean_s),
            fmt(self.p95_s),
            self.iters
        );
    }
}

pub fn fmt(s: f64) -> String {
    if s < 1e-6 {
        format!("{:.1} ns", s * 1e9)
    } else if s < 1e-3 {
        format!("{:.2} µs", s * 1e6)
    } else if s < 1.0 {
        format!("{:.2} ms", s * 1e3)
    } else {
        format!("{:.3} s", s)
    }
}

/// Run `f` repeatedly: `warmup` untimed, then timed iterations until
/// `min_time_s` elapses (at least `min_iters`).
pub fn bench(name: &str, warmup: usize, min_iters: usize, min_time_s: f64, mut f: impl FnMut()) -> BenchResult {
    for _ in 0..warmup {
        f();
    }
    let mut times = Vec::new();
    let start = Instant::now();
    while times.len() < min_iters || start.elapsed().as_secs_f64() < min_time_s {
        let t = Instant::now();
        f();
        times.push(t.elapsed().as_secs_f64());
        if times.len() > 10_000 {
            break;
        }
    }
    times.sort_by(|a, b| a.partial_cmp(b).unwrap());
    let n = times.len();
    let r = BenchResult {
        name: name.to_string(),
        iters: n,
        median_s: times[n / 2],
        mean_s: times.iter().sum::<f64>() / n as f64,
        p95_s: times[(n * 95 / 100).min(n - 1)],
    };
    r.print();
    r
}

/// Prevent the optimizer from discarding a value.
#[inline]
pub fn black_box<T>(x: T) -> T {
    std::hint::black_box(x)
}
