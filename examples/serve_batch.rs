//! Serving example: start the batching sampling service with a pre-trained
//! PAS dictionary, fire concurrent mixed requests at it, and report
//! latency / throughput / batching statistics.
//!
//! Run: `cargo run --release --example serve_batch`

use pas::experiments::common::default_train;
use pas::experiments::ExpOpts;
use pas::pas::train::PasTrainer;
use pas::schedule::default_schedule;
use pas::score::analytic::AnalyticEps;
use pas::server::{SamplingRequest, Service, ServiceConfig};
use pas::util::timer::Timer;
use std::sync::atomic::Ordering;
use std::time::Duration;

fn main() {
    // Pre-train one PAS dictionary the service can serve (`pas: true`).
    let opts = ExpOpts::quick();
    let ds = pas::data::registry::get("gmm2d").unwrap();
    let model = AnalyticEps::from_dataset(&ds);
    let solver = pas::solvers::registry::get("ddim").unwrap();
    let sched = default_schedule(10);
    let dict = PasTrainer::new(default_train(&opts, "ddim"))
        .train(solver.as_ref(), model.as_ref(), &sched, "gmm2d", false)
        .expect("training")
        .dict;
    println!("trained service-side PAS dict: {} params", dict.n_params());

    let svc = Service::start(
        ServiceConfig {
            workers: 4,
            max_batch: 512,
            batch_window: Duration::from_millis(4),
            queue_depth: 512,
            ..ServiceConfig::default()
        },
        vec![dict],
    );

    // Fire a burst of concurrent requests: two phases (pas off, then on)
    // so the dynamic batcher can fuse compatible neighbours.
    let t = Timer::start();
    let total_requests = 64;
    let rxs: Vec<_> = (0..total_requests)
        .map(|i| {
            svc.submit(SamplingRequest {
                id: 0,
                dataset: "gmm2d".into(),
                solver: "ddim".into(),
                nfe: 10,
                n_samples: 32,
                seed: i as u64,
                use_pas: i >= total_requests / 2,
                deadline_ms: None,
                priority: 0,
            })
            .expect("queue full")
        })
        .collect();
    let mut total_samples = 0usize;
    let mut lat = Vec::new();
    let mut fused_max = 0usize;
    for rx in rxs {
        let r = rx.recv().unwrap();
        assert!(r.error.is_none(), "{:?}", r.error);
        total_samples += r.n;
        lat.push(r.latency_ms);
        fused_max = fused_max.max(r.batched_with);
    }
    let wall = t.elapsed_s();
    lat.sort_by(|a, b| a.partial_cmp(b).unwrap());
    println!("== serve_batch results ==");
    println!("requests:        {total_requests} ({total_samples} samples total)");
    println!("wall time:       {:.1} ms", wall * 1e3);
    println!("throughput:      {:.0} samples/s", total_samples as f64 / wall);
    println!("latency p50/p95: {:.1} / {:.1} ms", lat[lat.len() / 2], lat[lat.len() * 95 / 100]);
    println!("max batch fusion: {fused_max} requests");
    println!(
        "batches formed:  {} (from {} fused requests)",
        svc.metrics.batches.load(Ordering::Relaxed),
        svc.metrics.fused_requests.load(Ordering::Relaxed)
    );
    svc.shutdown();
}
