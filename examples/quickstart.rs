//! Quickstart: train PAS for DDIM at 10 NFE on the CIFAR10 stand-in,
//! then sample with and without the correction and compare gFID.
//!
//! Run: `cargo run --release --example quickstart`

use pas::experiments::common::{default_train, Bench};
use pas::experiments::ExpOpts;
use pas::metrics::gfid;
use pas::pas::correct::CorrectedSampler;
use pas::pas::train::PasTrainer;
use pas::schedule::default_schedule;
use pas::solvers::run_solver;
use pas::traj::sample_prior;
use pas::util::rng::Pcg64;

fn main() {
    let opts = ExpOpts {
        n_samples: 2048,
        ..ExpOpts::default()
    };
    let bench = Bench::new("gmm-hd64", 0.0, &opts);
    let solver = pas::solvers::registry::get("ddim").unwrap();
    let nfe = 10;
    let sched = default_schedule(nfe);

    println!("== PAS quickstart: DDIM @ {nfe} NFE on gmm-hd64 (CIFAR10 stand-in) ==");

    // 1. Train the ~10 parameters.
    let trainer = PasTrainer::new(default_train(&opts, "ddim"));
    let tr = trainer
        .train(solver.as_ref(), bench.model.as_ref(), &sched, "gmm-hd64", false)
        .expect("training");
    println!(
        "trained in {:.2}s: corrected time points [{}] -> {} stored parameters",
        tr.train_seconds,
        tr.trace.corrected_steps_str(),
        tr.dict.n_params()
    );

    // 2. Sample fresh trajectories with and without PAS.
    let n = opts.n_samples;
    let dim = bench.dim();
    let mut rng = Pcg64::seed(123);
    let x_t = sample_prior(&mut rng, n, dim, sched.t_max());
    let plain = run_solver(solver.as_ref(), bench.model.as_ref(), &x_t, n, &sched, None);
    let corrected =
        CorrectedSampler::sample(&tr.dict, solver.as_ref(), bench.model.as_ref(), &x_t, n, &sched);

    // 3. Compare against 8192 reference samples from the data distribution.
    let f_plain = gfid(&plain.x0, n, &bench.reference, bench.n_ref, dim);
    let f_pas = gfid(&corrected.x0, n, &bench.reference, bench.n_ref, dim);
    println!("gFID ddim       = {f_plain:.4}");
    println!("gFID ddim + PAS = {f_pas:.4}");
    println!(
        "improvement: {:.2}x with {} parameters",
        f_plain / f_pas,
        tr.dict.n_params()
    );
    assert!(f_pas < f_plain, "PAS should improve DDIM");
}
