//! END-TO-END driver (EXPERIMENTS.md §E2E): the full three-layer system on
//! a real small workload.
//!
//! 1. Loads the **AOT-compiled JAX denoiser** (trained at build time on
//!    rust-exported data; Pallas resblock kernel inside) through the PJRT
//!    runtime — Python is not running.
//! 2. Generates teacher trajectories with Heun @ 100 NFE *on the PJRT
//!    model*, trains PAS for DDIM @ 10 NFE.
//! 3. Samples 1024 fresh trajectories with and without PAS, reports gFID
//!    against held-out data samples and the trajectory L1/L2 metrics.
//!
//! Requires `make artifacts` first. Run:
//! `cargo run --release --example paper_pipeline`

use pas::experiments::common::default_train;
use pas::experiments::ExpOpts;
use pas::metrics::{gfid, mean_l1, mean_l2};
use pas::pas::correct::CorrectedSampler;
use pas::pas::train::PasTrainer;
use pas::schedule::default_schedule;
use pas::score::pjrt::PjrtEps;
use pas::score::EpsModel;
use pas::solvers::run_solver;
use pas::traj::{ground_truth, sample_prior};
use pas::util::rng::Pcg64;
use pas::util::timer::Timer;

fn main() {
    let dataset = "gmm-hd64";
    let art_dir = pas::runtime::artifacts_dir();
    println!("== paper_pipeline: three-layer end-to-end on {dataset} ==");

    // L3 loads the L2/L1 artifact via PJRT.
    let rt = pas::runtime::Runtime::cpu().expect("PJRT CPU client");
    println!("PJRT platform: {}", rt.platform());
    let exe = rt
        .load_artifact(&art_dir, &format!("eps_{dataset}"))
        .expect("load artifact — run `make artifacts` first");
    println!(
        "loaded artifact eps_{dataset}: batch={} dim={}",
        exe.meta.batch, exe.meta.dim
    );
    let model = PjrtEps::new(exe);
    let dim = model.dim();

    // PAS training against the PJRT-backed denoiser.
    let nfe = 10;
    let sched = default_schedule(nfe);
    let solver = pas::solvers::registry::get("ddim").unwrap();
    let opts = ExpOpts {
        n_traj: 64,
        epochs: 24,
        ..ExpOpts::default()
    };
    let mut cfg = default_train(&opts, "ddim");
    cfg.teacher_nfe = 100;
    let t_train = Timer::start();
    let tr = PasTrainer::new(cfg)
        .train(solver.as_ref(), &model, &sched, dataset, false)
        .expect("PAS training");
    println!(
        "PAS trained on the PJRT model in {:.1}s: steps [{}], {} parameters",
        t_train.elapsed_s(),
        tr.trace.corrected_steps_str(),
        tr.dict.n_params()
    );

    // Fresh evaluation batch.
    let n = 1024;
    let mut rng = Pcg64::seed(2024);
    let x_t = sample_prior(&mut rng, n, dim, sched.t_max());
    let t_s = Timer::start();
    let plain = run_solver(solver.as_ref(), &model, &x_t, n, &sched, None);
    let t_plain = t_s.elapsed_s();
    let t_s = Timer::start();
    let corr = CorrectedSampler::sample(&tr.dict, solver.as_ref(), &model, &x_t, n, &sched);
    let t_corr = t_s.elapsed_s();

    // Ground truth endpoint for trajectory metrics (teacher on PJRT model).
    let teacher = pas::solvers::registry::get("heun").unwrap();
    let gt = ground_truth(teacher.as_ref(), &model, &x_t, n, &sched, 100);
    let gt0 = gt.node(gt.n_nodes() - 1);

    // Reference = the model's own flow: teacher samples from independent
    // priors. (The paper compares against data because its pre-trained
    // nets are near-perfect; our build-time MLP is not, so solver error is
    // measured against the flow the solver is actually discretizing —
    // DESIGN.md §3.)
    let n_ref = 2048;
    let mut rref = Pcg64::seed(77);
    let x_ref = sample_prior(&mut rref, n_ref, dim, sched.t_max());
    let fine = pas::schedule::default_schedule(50);
    let reference = run_solver(teacher.as_ref(), &model, &x_ref, n_ref, &fine, None).x0;

    let f_plain = gfid(&plain.x0, n, &reference, n_ref, dim);
    let f_corr = gfid(&corr.x0, n, &reference, n_ref, dim);
    println!("-- results (n={n}, NFE={nfe}; gFID vs the model's own flow) --");
    println!(
        "gFID:      ddim {f_plain:.4} -> ddim+PAS {f_corr:.4}  ({:.2}x better)",
        f_plain / f_corr
    );
    println!(
        "L2 vs GT:  {:.5} -> {:.5}",
        mean_l2(&plain.x0, gt0, n, dim),
        mean_l2(&corr.x0, gt0, n, dim)
    );
    println!(
        "L1 vs GT:  {:.5} -> {:.5}",
        mean_l1(&plain.x0, gt0, n, dim),
        mean_l1(&corr.x0, gt0, n, dim)
    );
    println!(
        "sampling:  {:.2}s plain vs {:.2}s corrected ({:.1}% overhead)",
        t_plain,
        t_corr,
        (t_corr / t_plain - 1.0) * 100.0
    );
    assert!(f_corr < f_plain, "PAS must improve the PJRT model too");
    println!("paper_pipeline OK");
}
