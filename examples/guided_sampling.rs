//! Guided (classifier-free) sampling — the Stable-Diffusion-shaped
//! workload of Table 3: a conditional GMM with guidance scale 7.5,
//! DDIM corrected by PAS.
//!
//! Run: `cargo run --release --example guided_sampling`

use pas::experiments::common::{default_train, Bench};
use pas::experiments::ExpOpts;
use pas::metrics::{gfid, sliced_w2};
use pas::pas::correct::CorrectedSampler;
use pas::pas::train::PasTrainer;
use pas::schedule::default_schedule;
use pas::solvers::run_solver;
use pas::traj::sample_prior;
use pas::util::rng::Pcg64;

fn main() {
    let opts = ExpOpts {
        n_samples: 1024,
        ..ExpOpts::default()
    };
    println!("== guided sampling (cond-gmm64, CFG scale 7.5) ==");
    let bench = Bench::new("cond-gmm64", 7.5, &opts);
    let solver = pas::solvers::registry::get("ddim").unwrap();

    for nfe in [5usize, 10] {
        let sched = default_schedule(nfe);
        let trainer = PasTrainer::new(default_train(&opts, "ddim"));
        let tr = trainer
            .train(solver.as_ref(), bench.model.as_ref(), &sched, "cond-gmm64", false)
            .expect("training");
        let n = opts.n_samples;
        let dim = bench.dim();
        let mut rng = Pcg64::seed(7);
        let x_t = sample_prior(&mut rng, n, dim, sched.t_max());
        let plain = run_solver(solver.as_ref(), bench.model.as_ref(), &x_t, n, &sched, None);
        let corr = CorrectedSampler::sample(
            &tr.dict,
            solver.as_ref(),
            bench.model.as_ref(),
            &x_t,
            n,
            &sched,
        );
        let f0 = gfid(&plain.x0, n, &bench.reference, bench.n_ref, dim);
        let f1 = gfid(&corr.x0, n, &bench.reference, bench.n_ref, dim);
        let w0 = sliced_w2(&plain.x0, n, &bench.reference, bench.n_ref, dim, 32, 3);
        let w1 = sliced_w2(&corr.x0, n, &bench.reference, bench.n_ref, dim, 32, 3);
        println!(
            "NFE {nfe:>2}: gFID {f0:8.3} -> {f1:8.3} | sliced-W2 {w0:8.3} -> {w1:8.3} | steps [{}] ({} params)",
            tr.trace.corrected_steps_str(),
            tr.dict.n_params()
        );
    }
}
