"""AOT export: lower the trained denoiser to HLO **text** artifacts.

For each exported dataset this produces

    artifacts/eps_<dataset>.hlo.txt    # HLO text, weights baked as consts
    artifacts/eps_<dataset>.meta.json  # {name, batch, dim, dataset}

which `rust/src/runtime` loads via ``HloModuleProto::from_text_file``.

HLO *text*, not ``.serialize()``: jax >= 0.5 emits protos with 64-bit
instruction ids which xla_extension 0.5.1 rejects; the text parser
reassigns ids (see /opt/xla-example/README.md).

Usage (normally via ``make artifacts``):
    python -m compile.aot --out-dir ../artifacts --data-dir ../artifacts/data
"""

import argparse
import json
import os

import jax
import jax.numpy as jnp
from jax._src.lib import xla_client as xc

from . import model, train_model

# Exported model variants: (dataset, hidden, n_blocks, train steps).
EXPORTS = [
    ("spiral2d", 96, 3, 2500),
    ("gmm-hd64", 128, 4, 2500),
]
BATCH = 64


def to_hlo_text(lowered):
    """stablehlo -> XlaComputation -> HLO text (the 0.5.1-safe path)."""
    mlir_mod = lowered.compiler_ir("stablehlo")
    comp = xc._xla.mlir.mlir_module_to_xla_computation(
        str(mlir_mod), use_tuple_args=False, return_tuple=True
    )
    return comp.as_hlo_text()


def export_eps(params, dim, batch=BATCH, use_pallas=True):
    """Lower eps(x, t) with weights closed over as constants."""

    def fn(x, t):
        return (model.eps_apply(params, x, t, use_pallas=use_pallas),)

    x_spec = jax.ShapeDtypeStruct((batch, dim), jnp.float32)
    t_spec = jax.ShapeDtypeStruct((batch,), jnp.float32)
    lowered = jax.jit(fn).lower(x_spec, t_spec)
    return to_hlo_text(lowered)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--out-dir", default="../artifacts")
    ap.add_argument("--data-dir", default="../artifacts/data")
    ap.add_argument("--steps", type=int, default=None, help="override train steps")
    ap.add_argument("--batch", type=int, default=BATCH)
    args = ap.parse_args()
    os.makedirs(args.out_dir, exist_ok=True)

    for dataset, hidden, n_blocks, steps in EXPORTS:
        data_prefix = os.path.join(args.data_dir, dataset)
        if not os.path.exists(data_prefix + ".bin"):
            raise SystemExit(
                f"missing {data_prefix}.bin — run `pas dump-data` first (make artifacts does this)"
            )
        cache = os.path.join(args.out_dir, f"weights_{dataset}.npz")
        print(f"[aot] {dataset}: training/loading denoiser (hidden={hidden})")
        params, loss = train_model.train_or_load(
            data_prefix,
            cache,
            hidden=hidden,
            n_blocks=n_blocks,
            steps=args.steps or steps,
        )
        with open(data_prefix + ".meta.json") as f:
            dim = json.load(f)["dim"]
        print(f"[aot] {dataset}: lowering eps(x, t) to HLO text (batch={args.batch})")
        hlo = export_eps(params, dim, batch=args.batch, use_pallas=True)
        name = f"eps_{dataset}"
        hlo_path = os.path.join(args.out_dir, f"{name}.hlo.txt")
        with open(hlo_path, "w") as f:
            f.write(hlo)
        meta = {"name": name, "batch": args.batch, "dim": dim, "dataset": dataset}
        with open(os.path.join(args.out_dir, f"{name}.meta.json"), "w") as f:
            json.dump(meta, f)
        print(f"[aot] wrote {hlo_path} ({len(hlo)} chars)")
        if loss is not None:
            print(f"[aot] {dataset}: final dsm loss {loss:.4f}")
    print("[aot] done")


if __name__ == "__main__":
    main()
