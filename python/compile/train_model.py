"""Build-time denoiser training (denoising score matching, EDM weighting).

Trains the L2 MLP denoiser on dataset samples exported by the rust side
(`pas dump-data`). Runs once during `make artifacts`; the resulting weights
are baked into the HLO artifact by aot.py. Never on the request path.
"""

import json
import os
from functools import partial

import jax
import jax.numpy as jnp
import numpy as np

from . import model

# EDM sigma sampling: log-normal, wider than EDM's default so the sampler's
# whole [0.002, 80] range is covered.
P_MEAN = -0.6
P_STD = 1.6


def load_dataset(prefix):
    """Load `<prefix>.bin` (+ `.meta.json`) written by `pas dump-data`."""
    with open(prefix + ".meta.json") as f:
        meta = json.load(f)
    x = np.fromfile(prefix + ".bin", dtype="<f4").reshape(meta["n"], meta["dim"])
    return jnp.asarray(x), meta


def dsm_loss(params, x0, key):
    """EDM-weighted denoising score matching loss."""
    b = x0.shape[0]
    k1, k2 = jax.random.split(key)
    sigma = jnp.exp(P_MEAN + P_STD * jax.random.normal(k1, (b,)))
    noise = jax.random.normal(k2, x0.shape)
    x_t = x0 + sigma[:, None] * noise
    d = model.denoise(params, x_t, sigma, use_pallas=False)
    w = (sigma**2 + model.SIGMA_DATA**2) / (sigma * model.SIGMA_DATA) ** 2
    return jnp.mean(w[:, None] * (d - x0) ** 2)


@partial(jax.jit, static_argnames=())
def adam_step(params, opt_m, opt_v, step, x0, key, lr):
    trainable = {k: v for k, v in params.items() if isinstance(v, jnp.ndarray)}
    grads = jax.grad(
        lambda tp: dsm_loss({**params, **tp}, x0, key)
    )(trainable)
    b1, b2, eps = 0.9, 0.999, 1e-8
    new_m, new_v, new_p = {}, {}, dict(params)
    for k, g in grads.items():
        new_m[k] = b1 * opt_m[k] + (1 - b1) * g
        new_v[k] = b2 * opt_v[k] + (1 - b2) * g * g
        mh = new_m[k] / (1 - b1**step)
        vh = new_v[k] / (1 - b2**step)
        new_p[k] = params[k] - lr * mh / (jnp.sqrt(vh) + eps)
    return new_p, new_m, new_v


def train(
    data_prefix,
    hidden=128,
    n_blocks=4,
    steps=2500,
    batch=256,
    lr=2e-3,
    seed=0,
    log_every=500,
):
    """Train a denoiser; returns (params, meta, final_loss)."""
    x, meta = load_dataset(data_prefix)
    dim = meta["dim"]
    key = jax.random.PRNGKey(seed)
    key, k_init = jax.random.split(key)
    params = model.init_params(k_init, dim, hidden=hidden, n_blocks=n_blocks)
    trainable = {k: v for k, v in params.items() if isinstance(v, jnp.ndarray)}
    opt_m = {k: jnp.zeros_like(v) for k, v in trainable.items()}
    opt_v = {k: jnp.zeros_like(v) for k, v in trainable.items()}
    n = x.shape[0]
    last = None
    for step in range(1, steps + 1):
        key, k_batch, k_loss = jax.random.split(key, 3)
        idx = jax.random.randint(k_batch, (batch,), 0, n)
        x0 = x[idx]
        params, opt_m, opt_v = adam_step(
            params, opt_m, opt_v, step, x0, k_loss, lr
        )
        if step % log_every == 0 or step == steps:
            key, k_eval = jax.random.split(key)
            last = float(dsm_loss(params, x[:1024], k_eval))
            print(f"  [train {meta['dataset']}] step {step}: dsm loss {last:.4f}")
    return params, meta, last


def train_or_load(data_prefix, cache_path, **kw):
    """Train unless cached weights exist (make artifacts is incremental)."""
    if os.path.exists(cache_path):
        return model.load_params(cache_path), None
    params, meta, loss = train(data_prefix, **kw)
    os.makedirs(os.path.dirname(cache_path), exist_ok=True)
    model.save_params(params, cache_path)
    return params, loss
