"""L2 — the JAX denoiser (EDM-preconditioned residual MLP).

``eps_apply(params, x, t)`` predicts the noise for a batch under the EDM
parameterization used throughout the rust coordinator:

    c_in    = 1 / sqrt(t^2 + sigma_data^2)
    c_skip  = sigma_data^2 / (t^2 + sigma_data^2)
    c_out   = t * sigma_data / sqrt(t^2 + sigma_data^2)
    c_noise = log(t) / 4
    D(x, t) = c_skip * x + c_out * F(c_in * x, c_noise)       # x0 prediction
    eps     = (x - D) / t

The network body F is: input proj -> K fused residual blocks (the L1
Pallas kernel) with per-block projected Fourier time embeddings -> output
proj. Everything is f32; weights are baked into the AOT artifact as
constants by aot.py.
"""

from functools import partial

import jax
import jax.numpy as jnp

from .kernels import ref
from .kernels.fused_resblock import fused_resblock

SIGMA_DATA = 1.0
N_FOURIER = 16


def init_params(key, dim, hidden=128, n_blocks=4):
    """Initialize model parameters (a flat dict of jnp arrays)."""
    keys = jax.random.split(key, 3 + 3 * n_blocks)
    # NOTE: params holds ONLY jnp arrays (jit traces every leaf); structural
    # metadata like n_blocks is inferred from the key set.
    params = {
        # Fixed random Fourier frequencies for the time embedding.
        "freqs": jax.random.normal(keys[0], (N_FOURIER,)) * 2.0,
        "w_in": jax.random.normal(keys[1], (dim, hidden)) / jnp.sqrt(dim),
        "b_in": jnp.zeros((hidden,)),
        "w_out": jnp.zeros((hidden, dim)),  # zero-init output: F(x)=0 at start
        "b_out": jnp.zeros((dim,)),
    }
    for k in range(n_blocks):
        params[f"blk{k}_w1"] = (
            jax.random.normal(keys[3 + 3 * k], (hidden, hidden)) / jnp.sqrt(hidden)
        )
        params[f"blk{k}_b1"] = jnp.zeros((hidden,))
        params[f"blk{k}_w2"] = (
            jax.random.normal(keys[4 + 3 * k], (hidden, hidden))
            / jnp.sqrt(hidden)
            * 0.5
        )
        params[f"blk{k}_b2"] = jnp.zeros((hidden,))
        params[f"blk{k}_temb"] = (
            jax.random.normal(keys[5 + 3 * k], (2 * N_FOURIER, hidden))
            / jnp.sqrt(2 * N_FOURIER)
        )
    return params


def n_blocks_of(params):
    """Infer the block count from the parameter key structure (static)."""
    return len([k for k in params if k.endswith("_temb")])


def time_embed(params, c_noise):
    """Fourier features of the conditioning noise level, (B, 2*N_FOURIER)."""
    ang = c_noise[:, None] * params["freqs"][None, :]
    return jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], axis=-1)


def body(params, x_in, c_noise, use_pallas):
    """The raw network F(c_in * x, c_noise)."""
    emb = time_embed(params, c_noise)
    h = x_in @ params["w_in"] + params["b_in"][None, :]
    for k in range(n_blocks_of(params)):
        temb = emb @ params[f"blk{k}_temb"]
        args = (
            h,
            temb,
            params[f"blk{k}_w1"],
            params[f"blk{k}_b1"],
            params[f"blk{k}_w2"],
            params[f"blk{k}_b2"],
        )
        h = fused_resblock(*args) if use_pallas else ref.resblock_ref(*args)
    return h @ params["w_out"] + params["b_out"][None, :]


@partial(jax.jit, static_argnames=("use_pallas",))
def denoise(params, x, t, use_pallas=False):
    """EDM x0-prediction D(x, t). x: (B, D); t: (B,)."""
    t = t[:, None]
    c_in = 1.0 / jnp.sqrt(t**2 + SIGMA_DATA**2)
    c_skip = SIGMA_DATA**2 / (t**2 + SIGMA_DATA**2)
    c_out = t * SIGMA_DATA / jnp.sqrt(t**2 + SIGMA_DATA**2)
    c_noise = jnp.log(t[:, 0]) / 4.0
    f = body(params, c_in * x, c_noise, use_pallas)
    return c_skip * x + c_out * f


@partial(jax.jit, static_argnames=("use_pallas",))
def eps_apply(params, x, t, use_pallas=False):
    """Noise prediction eps(x, t) = (x - D(x, t)) / t."""
    d = denoise(params, x, t, use_pallas=use_pallas)
    return (x - d) / t[:, None]


def save_params(params, path):
    import numpy as np

    np.savez(path, **{k: np.asarray(v) for k, v in params.items()})


def load_params(path):
    import numpy as np

    z = np.load(path)
    return {k: jnp.asarray(z[k], dtype=jnp.float32) for k in z.files}
