"""Pure-jnp oracle for the Pallas kernels — the CORE correctness signal.

Every kernel in this package must match its reference here to float32
tolerance under pytest (including the hypothesis shape/seed sweeps in
python/tests/test_kernel.py).
"""

import jax
import jax.numpy as jnp


def resblock_ref(x, temb, w1, b1, w2, b2):
    """Reference for fused_resblock: y = x + silu(x@w1 + b1 + temb) @ w2 + b2."""
    h = x @ w1 + b1[None, :] + temb
    h = h * jax.nn.sigmoid(h)
    return x + h @ w2 + b2[None, :]


def silu(x):
    return x * jax.nn.sigmoid(x)


def mlp_ref(x, w, b):
    """Plain affine layer reference (used by model tests)."""
    return x @ w + b[None, :]
