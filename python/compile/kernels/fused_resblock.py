"""L1 — Pallas kernel: fused residual MLP block.

The denoiser's hot spot is the residual block

    y = x + silu(x @ w1 + b1 + temb) @ w2 + b2

executed once per layer per NFE. On a real TPU this is two MXU matmuls with
the SiLU fused between them; the BlockSpec tiles the *batch* dimension
(weights stay VMEM-resident across grid steps because they are constants of
the AOT-compiled executable). Here we run under ``interpret=True`` — the
CPU PJRT plugin cannot execute Mosaic custom-calls — so the kernel lowers
to plain HLO ops and numerics are validated against ``ref.py`` by pytest.

TPU sizing (DESIGN.md §Hardware-Adaptation): with H = 128 and block_b = 64
the per-step VMEM footprint is
  2 weight tiles (128x128 f32)  = 128 KiB
  x/temb/out tiles (64x128 f32) = 96 KiB
  hidden tile                   = 32 KiB
well under the ~16 MiB VMEM budget; both matmuls hit the 128x128 MXU
natively.
"""

from functools import partial

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

# Batch tile. 64 rows x 128 features = one MXU-friendly tile.
DEFAULT_BLOCK_B = 64


def _resblock_kernel(x_ref, temb_ref, w1_ref, b1_ref, w2_ref, b2_ref, o_ref):
    """One batch tile: out = x + silu(x@w1 + b1 + temb) @ w2 + b2."""
    x = x_ref[...]
    h = jnp.dot(x, w1_ref[...], preferred_element_type=jnp.float32)
    h = h + b1_ref[...][None, :] + temb_ref[...]
    h = h * jax.nn.sigmoid(h)  # silu, fused between the two matmuls
    y = jnp.dot(h, w2_ref[...], preferred_element_type=jnp.float32)
    o_ref[...] = x + y + b2_ref[...][None, :]


@partial(jax.jit, static_argnames=("block_b",))
def fused_resblock(x, temb, w1, b1, w2, b2, block_b=DEFAULT_BLOCK_B):
    """Fused residual MLP block via Pallas (interpret mode).

    Args:
      x:    (B, H) activations.
      temb: (B, H) per-row time embedding, added pre-activation.
      w1, b1, w2, b2: block weights, (H, H)/(H,).
      block_b: batch tile size; B must be a multiple (pad upstream).

    Returns: (B, H).
    """
    b, h = x.shape
    assert temb.shape == (b, h), (x.shape, temb.shape)
    assert w1.shape == (h, h) and w2.shape == (h, h)
    if b % block_b != 0:
        block_b = b  # degenerate single-tile fallback for odd batches
    grid = (b // block_b,)
    return pl.pallas_call(
        _resblock_kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((block_b, h), lambda i: (i, 0)),  # x: stream batch
            pl.BlockSpec((block_b, h), lambda i: (i, 0)),  # temb
            pl.BlockSpec((h, h), lambda i: (0, 0)),  # w1: resident
            pl.BlockSpec((h,), lambda i: (0,)),
            pl.BlockSpec((h, h), lambda i: (0, 0)),  # w2: resident
            pl.BlockSpec((h,), lambda i: (0,)),
        ],
        out_specs=pl.BlockSpec((block_b, h), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((b, h), jnp.float32),
        interpret=True,  # CPU PJRT cannot run Mosaic custom-calls
    )(x, temb, w1, b1, w2, b2)
