"""Build-path tests: DSM training makes progress; AOT lowering produces
valid HLO text that the 0.5.1-era parser conventions accept."""

import json
import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import aot, model, train_model


@pytest.fixture(scope="module")
def tiny_dataset(tmp_path_factory):
    """A 2-mode 2-D GMM written in the dump-data format."""
    d = tmp_path_factory.mktemp("data")
    rng = np.random.default_rng(0)
    n = 4000
    means = np.array([[3.0, 0.0], [-3.0, 0.0]])
    x = means[rng.integers(0, 2, n)] + 0.3 * rng.standard_normal((n, 2))
    prefix = str(d / "toy2d")
    x.astype("<f4").tofile(prefix + ".bin")
    with open(prefix + ".meta.json", "w") as f:
        json.dump({"dataset": "toy2d", "n": n, "dim": 2, "seed": 0}, f)
    return prefix


def test_training_reduces_loss(tiny_dataset):
    x, meta = train_model.load_dataset(tiny_dataset)
    assert x.shape == (4000, 2)
    params0 = model.init_params(jax.random.PRNGKey(1), 2, hidden=32, n_blocks=2)
    k = jax.random.PRNGKey(2)
    loss0 = float(train_model.dsm_loss(params0, x[:1024], k))
    params, meta2, loss1 = train_model.train(
        tiny_dataset, hidden=32, n_blocks=2, steps=200, batch=128, log_every=200
    )
    assert loss1 < loss0 * 0.9, (loss0, loss1)


def test_trained_denoiser_pulls_toward_modes(tiny_dataset):
    params, _, _ = train_model.train(
        tiny_dataset, hidden=32, n_blocks=2, steps=400, batch=128, log_every=400
    )
    # At small sigma, D(x, t) near a mode should move toward it.
    x = jnp.asarray([[3.3, 0.1], [-3.3, -0.1]])
    t = jnp.full((2,), 0.5)
    d = model.denoise(params, x, t)
    assert abs(float(d[0, 0]) - 3.0) < abs(3.3 - 3.0) + 0.2
    assert float(d[0, 0]) > 1.0  # stays near the +3 mode
    assert float(d[1, 0]) < -1.0


def test_aot_export_produces_hlo_text(tiny_dataset):
    params = model.init_params(jax.random.PRNGKey(3), 2, hidden=32, n_blocks=2)
    hlo = aot.export_eps(params, dim=2, batch=8, use_pallas=True)
    assert "HloModule" in hlo
    assert "ENTRY" in hlo
    # Weights baked as constants: the ENTRY signature takes exactly
    # (x: f32[8,2], t: f32[8]) and returns a 1-tuple.
    assert "entry_computation_layout={(f32[8,2]{1,0}, f32[8]{0})->(f32[8,2]{1,0})}" in hlo
    # No Mosaic custom-calls (interpret mode lowers to plain HLO).
    assert "mosaic" not in hlo.lower()


def test_exported_fn_matches_jax_numerics(tiny_dataset):
    """Round-trip the lowered computation through XLA's own compiler and
    compare against the jitted function."""
    from jax._src.lib import xla_client as xc

    params = model.init_params(jax.random.PRNGKey(4), 2, hidden=16, n_blocks=1)

    def fn(x, t):
        return (model.eps_apply(params, x, t, use_pallas=False),)

    x = jax.random.normal(jax.random.PRNGKey(5), (4, 2), jnp.float32)
    t = jnp.full((4,), 1.3, jnp.float32)
    want = fn(x, t)[0]
    lowered = jax.jit(fn).lower(
        jax.ShapeDtypeStruct((4, 2), jnp.float32), jax.ShapeDtypeStruct((4,), jnp.float32)
    )
    hlo_text = aot.to_hlo_text(lowered)
    # Compile the HLO text with the local CPU client.
    client = xc._xla.get_local_client("cpu") if hasattr(xc._xla, "get_local_client") else None
    if client is None:
        pytest.skip("no local client accessor in this jax version")
    got = None
    try:
        comp = xc._xla.mlir.mlir_module_to_xla_computation(
            str(lowered.compiler_ir("stablehlo")), use_tuple_args=False, return_tuple=True
        )
        executable = client.compile(comp.as_serialized_hlo_module_proto())
        got = executable.execute([np.asarray(x), np.asarray(t)])[0]
    except Exception:
        pytest.skip("client.compile path unavailable; rust side covers execution")
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)
    assert len(hlo_text) > 100
