"""L2 model tests: shapes, EDM preconditioning identities, pallas/ref parity."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from compile import model


@pytest.fixture(scope="module")
def params():
    return model.init_params(jax.random.PRNGKey(0), dim=8, hidden=32, n_blocks=2)


def test_shapes(params):
    x = jax.random.normal(jax.random.PRNGKey(1), (16, 8))
    t = jnp.full((16,), 2.0)
    d = model.denoise(params, x, t)
    e = model.eps_apply(params, x, t)
    assert d.shape == (16, 8)
    assert e.shape == (16, 8)


def test_eps_denoise_identity(params):
    """eps = (x - D)/t must hold exactly."""
    x = jax.random.normal(jax.random.PRNGKey(2), (8, 8))
    t = jnp.full((8,), 0.7)
    d = model.denoise(params, x, t)
    e = model.eps_apply(params, x, t)
    np.testing.assert_allclose(
        np.asarray(e), np.asarray((x - d) / 0.7), rtol=1e-6, atol=1e-6
    )


def test_zero_init_network_is_cskip_only(params):
    """With w_out = 0 (the init), D(x,t) = c_skip * x exactly."""
    fresh = model.init_params(jax.random.PRNGKey(3), dim=4, hidden=16, n_blocks=1)
    x = jax.random.normal(jax.random.PRNGKey(4), (5, 4))
    t = jnp.full((5,), 3.0)
    d = model.denoise(fresh, x, t)
    c_skip = model.SIGMA_DATA**2 / (9.0 + model.SIGMA_DATA**2)
    np.testing.assert_allclose(np.asarray(d), c_skip * np.asarray(x), rtol=1e-6)


def test_pallas_and_ref_paths_agree(params):
    x = jax.random.normal(jax.random.PRNGKey(5), (64, 8))
    t = jnp.exp(jax.random.normal(jax.random.PRNGKey(6), (64,)))
    a = model.eps_apply(params, x, t, use_pallas=False)
    b = model.eps_apply(params, x, t, use_pallas=True)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)


def test_finite_across_sigma_range(params):
    """The sampler hits t in [0.002, 80]; outputs must stay finite."""
    x = jax.random.normal(jax.random.PRNGKey(7), (4, 8)) * 80.0
    for t_val in [0.002, 0.1, 1.0, 10.0, 80.0]:
        e = model.eps_apply(params, x, jnp.full((4,), t_val))
        assert bool(jnp.isfinite(e).all()), t_val


def test_params_save_load_roundtrip(tmp_path, params):
    p = str(tmp_path / "w.npz")
    model.save_params(params, p)
    back = model.load_params(p)
    assert set(back) == set(params)
    x = jax.random.normal(jax.random.PRNGKey(8), (4, 8))
    t = jnp.full((4,), 1.5)
    a = model.eps_apply(params, x, t)
    b = model.eps_apply(back, x, t)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-6)
