"""L1 kernel correctness: Pallas fused_resblock vs the pure-jnp oracle.

Includes a hypothesis sweep over shapes and seeds — the grid/BlockSpec
logic must be exact for every (batch, hidden) the model can produce.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from compile.kernels import ref
from compile.kernels.fused_resblock import fused_resblock


def make_inputs(key, b, h, dtype=jnp.float32):
    ks = jax.random.split(key, 6)
    return (
        jax.random.normal(ks[0], (b, h), dtype),
        jax.random.normal(ks[1], (b, h), dtype) * 0.3,
        jax.random.normal(ks[2], (h, h), dtype) / np.sqrt(h),
        jax.random.normal(ks[3], (h,), dtype) * 0.1,
        jax.random.normal(ks[4], (h, h), dtype) / np.sqrt(h),
        jax.random.normal(ks[5], (h,), dtype) * 0.1,
    )


def test_matches_ref_basic():
    args = make_inputs(jax.random.PRNGKey(0), 64, 128)
    got = fused_resblock(*args)
    want = ref.resblock_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_multi_tile_batch():
    # 256 rows = 4 grid steps of the default 64-row tile.
    args = make_inputs(jax.random.PRNGKey(1), 256, 64)
    got = fused_resblock(*args)
    want = ref.resblock_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_non_multiple_batch_falls_back():
    args = make_inputs(jax.random.PRNGKey(2), 50, 32)
    got = fused_resblock(*args)
    want = ref.resblock_ref(*args)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=1e-5, atol=1e-5)


def test_zero_weights_identity():
    b, h = 8, 16
    x = jax.random.normal(jax.random.PRNGKey(3), (b, h))
    z2 = jnp.zeros((h, h))
    zb = jnp.zeros((h,))
    got = fused_resblock(x, jnp.zeros((b, h)), z2, zb, z2, zb)
    # w2 = 0 -> the block is the identity.
    np.testing.assert_allclose(np.asarray(got), np.asarray(x), atol=1e-7)


@settings(max_examples=25, deadline=None)
@given(
    b=st.sampled_from([1, 2, 8, 16, 64, 96, 128]),
    h=st.sampled_from([8, 16, 32, 64, 128]),
    seed=st.integers(min_value=0, max_value=2**31 - 1),
)
def test_hypothesis_shape_sweep(b, h, seed):
    args = make_inputs(jax.random.PRNGKey(seed), b, h)
    got = fused_resblock(*args)
    want = ref.resblock_ref(*args)
    assert got.shape == (b, h)
    np.testing.assert_allclose(np.asarray(got), np.asarray(want), rtol=2e-5, atol=2e-5)


@settings(max_examples=10, deadline=None)
@given(scale=st.floats(min_value=1e-3, max_value=1e3), seed=st.integers(0, 1000))
def test_hypothesis_scale_robustness(scale, seed):
    """Kernel must stay finite and match ref across input magnitudes."""
    x, temb, w1, b1, w2, b2 = make_inputs(jax.random.PRNGKey(seed), 16, 32)
    x = x * scale
    got = fused_resblock(x, temb, w1, b1, w2, b2)
    want = ref.resblock_ref(x, temb, w1, b1, w2, b2)
    assert bool(jnp.isfinite(got).all())
    np.testing.assert_allclose(
        np.asarray(got), np.asarray(want), rtol=1e-4, atol=1e-4 * scale
    )


def test_gradients_flow_through_ref_path():
    """DSM training differentiates the *ref* path (pallas_call under
    interpret=True has no VJP); the kernel is the inference/export path.
    The two must agree numerically (covered above), and the ref must be
    differentiable."""
    args = make_inputs(jax.random.PRNGKey(4), 16, 32)

    def loss(w1):
        x, temb, _, b1, w2, b2 = args
        return jnp.sum(ref.resblock_ref(x, temb, w1, b1, w2, b2) ** 2)

    g = jax.grad(loss)(args[2])
    assert g.shape == (32, 32)
    assert bool(jnp.isfinite(g).all())
    assert float(jnp.abs(g).max()) > 0.0
